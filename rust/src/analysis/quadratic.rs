//! Closed-form optimization problems implementing [`Trainer`].
//!
//! The paper's convergence guarantees (Theorems 1–2) are statements about
//! `E[F(x_T) − F(x*)]`.  On a neural network that gap is unobservable, so
//! we validate the theory on problems where it is exact:
//!
//! * [`QuadraticProblem`] — each device holds
//!   `F_i(x) = ½·(x−c_i)ᵀ·D_i·(x−c_i)` with diagonal curvatures
//!   `D_i ∈ [μ, L]` and distinct centers `c_i` (the non-IID-ness).  The
//!   global `F = (1/n)·Σ F_i` is L-smooth and μ-strongly convex with a
//!   closed-form minimizer — Theorem 1 territory.
//! * [`WeaklyConvexProblem`] — the quadratic plus a `w·Σ_j cos(x_j)`
//!   ripple, which is `w`-weakly convex (Definition 3): non-convex but
//!   `F(x) + w/2·‖x‖²` convex.  Theorem 2 territory (Option II).
//!
//! Both run through the *same* coordinator code as the PJRT model, so the
//! theory checks also exercise the production control path.

use std::cell::RefCell;

use crate::coordinator::Trainer;
use crate::federated::data::Dataset;
use crate::federated::device::SimDevice;
use crate::runtime::{EvalMetrics, ParamVec, RuntimeError};
use crate::util::rng::Rng;

/// Strongly convex per-device quadratics with a shared closed form.
pub struct QuadraticProblem {
    pub dim: usize,
    /// `n × dim` device centers.
    pub(crate) centers: Vec<Vec<f32>>,
    /// `n × dim` diagonal curvatures, in `[mu, l]`.
    pub(crate) curvatures: Vec<Vec<f32>>,
    /// Std-dev of the additive gradient noise (≈ √V1).
    pub noise_std: f64,
    /// Local iterations per task (H).
    pub h: usize,
    /// Closed-form global minimizer and value.
    x_star: Vec<f64>,
    f_star: f64,
    pub mu: f64,
    pub l: f64,
    rng: RefCell<Rng>,
    init_scale: f64,
}

impl QuadraticProblem {
    /// Build a problem with `n` devices in `dim` dimensions, curvature
    /// range `[mu, l]`, center spread `spread`, gradient noise `noise_std`.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        n: usize,
        dim: usize,
        mu: f64,
        l: f64,
        spread: f64,
        noise_std: f64,
        h: usize,
        seed: u64,
    ) -> QuadraticProblem {
        assert!(mu > 0.0 && l >= mu);
        let mut rng = Rng::seed_from(seed ^ 0x0BAD_F00D);
        let centers: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| (rng.gaussian() * spread) as f32).collect())
            .collect();
        let curvatures: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.uniform(mu, l) as f32).collect())
            .collect();
        // x*_j = (Σ_i d_ij·c_ij) / (Σ_i d_ij); F* = F(x*).
        let mut x_star = vec![0.0f64; dim];
        for j in 0..dim {
            let (mut num, mut den) = (0.0f64, 0.0f64);
            for i in 0..n {
                num += curvatures[i][j] as f64 * centers[i][j] as f64;
                den += curvatures[i][j] as f64;
            }
            x_star[j] = num / den;
        }
        let mut problem = QuadraticProblem {
            dim,
            centers,
            curvatures,
            noise_std,
            h,
            x_star,
            f_star: 0.0,
            mu,
            l,
            rng: RefCell::new(rng),
            init_scale: spread.max(1.0) * 2.0,
        };
        let xs: Vec<f32> = problem.x_star.iter().map(|&v| v as f32).collect();
        problem.f_star = problem.global_f(&xs);
        problem
    }

    /// Global objective `F(x)`.
    pub fn global_f(&self, x: &[f32]) -> f64 {
        let n = self.centers.len();
        let mut total = 0.0f64;
        for i in 0..n {
            for j in 0..self.dim {
                let d = (x[j] - self.centers[i][j]) as f64;
                total += 0.5 * self.curvatures[i][j] as f64 * d * d;
            }
        }
        total / n as f64
    }

    /// Optimality gap `F(x) − F(x*)` (the quantity in Theorems 1–2).
    pub fn gap(&self, x: &[f32]) -> f64 {
        (self.global_f(x) - self.f_star).max(0.0)
    }

    pub fn x_star(&self) -> Vec<f32> {
        self.x_star.iter().map(|&v| v as f32).collect()
    }

    fn device_grad(&self, device: usize, x: &[f32], out: &mut [f64]) {
        if device == crate::coordinator::sgd::CENTRALIZED_DEVICE {
            // The centralized SGD baseline sees the *global* objective.
            let n = self.centers.len();
            for j in 0..self.dim {
                out[j] = (0..n)
                    .map(|i| {
                        self.curvatures[i][j] as f64 * (x[j] - self.centers[i][j]) as f64
                    })
                    .sum::<f64>()
                    / n as f64;
            }
            return;
        }
        for j in 0..self.dim {
            out[j] = self.curvatures[device][j] as f64
                * (x[j] - self.centers[device][j]) as f64;
        }
    }
}

impl Trainer for QuadraticProblem {
    fn param_count(&self) -> usize {
        self.dim
    }

    fn init_params(&self, seed_idx: usize) -> Result<ParamVec, RuntimeError> {
        let mut rng = Rng::seed_from(0x1217 + seed_idx as u64);
        Ok((0..self.dim)
            .map(|_| (rng.gaussian() * self.init_scale) as f32)
            .collect())
    }

    fn local_train(
        &self,
        params: &[f32],
        anchor: Option<&[f32]>,
        device: &mut SimDevice,
        _data: &Dataset,
        gamma: f32,
        rho: f32,
    ) -> Result<(ParamVec, f32), RuntimeError> {
        let i = if device.id == crate::coordinator::sgd::CENTRALIZED_DEVICE {
            device.id
        } else {
            device.id % self.centers.len()
        };
        let mut x: Vec<f32> = params.to_vec();
        let mut g = vec![0.0f64; self.dim];
        let mut rng = self.rng.borrow_mut();
        let mut last_f = 0.0f64;
        for _ in 0..self.h {
            self.device_grad(i, &x, &mut g);
            for j in 0..self.dim {
                let noise = if self.noise_std > 0.0 {
                    rng.gaussian() * self.noise_std
                } else {
                    0.0
                };
                let mut gj = g[j] + noise;
                if let Some(a) = anchor {
                    gj += rho as f64 * (x[j] - a[j]) as f64;
                }
                x[j] -= gamma * gj as f32;
            }
            last_f = self.global_f(&x);
        }
        Ok((x, last_f as f32))
    }

    fn evaluate(&self, params: &[f32], _test: &Dataset) -> Result<EvalMetrics, RuntimeError> {
        let gap = self.gap(params);
        Ok(EvalMetrics {
            loss: gap,
            // Monotone proxy so "accuracy" plots still slope the right way.
            accuracy: 1.0 / (1.0 + gap),
            samples: 1,
        })
    }

    fn local_iters(&self) -> usize {
        self.h
    }
}

/// Quadratic + `w·Σ cos(x_j)`: `w`-weakly convex (paper Definition 3).
pub struct WeaklyConvexProblem {
    pub base: QuadraticProblem,
    /// Weak-convexity modulus `w` (= μ in Definition 3).
    pub w: f64,
}

impl WeaklyConvexProblem {
    pub fn new(base: QuadraticProblem, w: f64) -> WeaklyConvexProblem {
        assert!(w >= 0.0);
        WeaklyConvexProblem { base, w }
    }

    pub fn global_f(&self, x: &[f32]) -> f64 {
        self.base.global_f(x) + self.w * x.iter().map(|&v| (v as f64).cos()).sum::<f64>()
    }

    /// Numerically locate the global optimum near the quadratic minimizer
    /// (valid when `w ≪ μ·spread`: the ripple only shifts the basin).
    pub fn approx_f_star(&self) -> f64 {
        let mut x = self.base.x_star();
        // Deterministic gradient descent on the true F (no noise).
        for _ in 0..2000 {
            for j in 0..x.len() {
                let mut g = 0.0f64;
                let n = self.base.centers.len();
                for i in 0..n {
                    g += self.base.curvatures[i][j] as f64
                        * (x[j] - self.base.centers[i][j]) as f64;
                }
                g /= n as f64;
                g -= self.w * (x[j] as f64).sin();
                x[j] -= 0.1 * g as f32;
            }
        }
        self.global_f(&x)
    }
}

impl Trainer for WeaklyConvexProblem {
    fn param_count(&self) -> usize {
        self.base.dim
    }

    fn init_params(&self, seed_idx: usize) -> Result<ParamVec, RuntimeError> {
        self.base.init_params(seed_idx)
    }

    fn local_train(
        &self,
        params: &[f32],
        anchor: Option<&[f32]>,
        device: &mut SimDevice,
        _data: &Dataset,
        gamma: f32,
        rho: f32,
    ) -> Result<(ParamVec, f32), RuntimeError> {
        let i = if device.id == crate::coordinator::sgd::CENTRALIZED_DEVICE {
            device.id
        } else {
            device.id % self.base.centers.len()
        };
        let mut x: Vec<f32> = params.to_vec();
        let mut g = vec![0.0f64; self.base.dim];
        let mut rng = self.base.rng.borrow_mut();
        for _ in 0..self.base.h {
            self.base.device_grad(i, &x, &mut g);
            for j in 0..self.base.dim {
                let noise = if self.base.noise_std > 0.0 {
                    rng.gaussian() * self.base.noise_std
                } else {
                    0.0
                };
                // d/dx_j [w·cos(x_j)] = −w·sin(x_j)
                let mut gj = g[j] - self.w * (x[j] as f64).sin() + noise;
                if let Some(a) = anchor {
                    gj += rho as f64 * (x[j] - a[j]) as f64;
                }
                x[j] -= gamma * gj as f32;
            }
        }
        let f = self.global_f(&x);
        Ok((x, f as f32))
    }

    fn evaluate(&self, params: &[f32], _test: &Dataset) -> Result<EvalMetrics, RuntimeError> {
        let gap = (self.global_f(params) - self.approx_f_star()).max(0.0);
        Ok(EvalMetrics { loss: gap, accuracy: 1.0 / (1.0 + gap), samples: 1 })
    }

    fn local_iters(&self) -> usize {
        self.base.h
    }
}

/// Theorem 1's contraction factor `β = 1 − α + α(1 − γμ)^{H_min}`.
pub fn beta_theorem1(alpha: f64, gamma: f64, mu: f64, h_min: usize) -> f64 {
    1.0 - alpha + alpha * (1.0 - gamma * mu).powi(h_min as i32)
}

/// Theorem 2's contraction factor `β = 1 − α + α(1 − γ(ρ−μ)/2)^{H_min}`.
pub fn beta_theorem2(alpha: f64, gamma: f64, rho: f64, mu: f64, h_min: usize) -> f64 {
    1.0 - alpha + alpha * (1.0 - gamma * (rho - mu) / 2.0).powi(h_min as i32)
}

/// Dummy dataset/fleet pieces so closed-form problems can reuse the
/// federated coordinators (which thread `&Dataset` and `&mut SimDevice`
/// through to the trainer).
pub fn dummy_dataset() -> Dataset {
    Dataset { features: vec![0.0; 4], labels: vec![0], input_size: 4, num_classes: 10 }
}

/// Fleet of `n` trivial devices (id is all the quadratic trainer reads).
pub fn dummy_fleet(n: usize, seed: u64) -> Vec<SimDevice> {
    use crate::federated::device::AvailabilityModel;
    let mut rng = Rng::seed_from(seed);
    (0..n)
        .map(|id| {
            SimDevice::new(
                id,
                vec![0],
                1.0,
                AvailabilityModel { mean_up: 1e18, mean_down: 1e-9 },
                rng.split(),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn problem(noise: f64) -> QuadraticProblem {
        QuadraticProblem::new(10, 8, 0.5, 2.0, 3.0, noise, 5, 42)
    }

    #[test]
    fn x_star_is_a_stationary_point() {
        let p = problem(0.0);
        let xs = p.x_star();
        // Mean gradient at x* must vanish.
        let n = p.centers.len();
        for j in 0..p.dim {
            let g: f64 = (0..n)
                .map(|i| p.curvatures[i][j] as f64 * (xs[j] - p.centers[i][j]) as f64)
                .sum::<f64>()
                / n as f64;
            assert!(g.abs() < 1e-5, "grad[{j}]={g}");
        }
        assert!(p.gap(&xs) < 1e-9);
    }

    #[test]
    fn gap_is_positive_away_from_optimum() {
        let p = problem(0.0);
        let mut x = p.x_star();
        x[0] += 1.0;
        assert!(p.gap(&x) > 0.1);
    }

    #[test]
    fn local_train_descends_device_objective() {
        let p = problem(0.0);
        let data = dummy_dataset();
        let mut fleet = dummy_fleet(4, 1);
        let x0 = Trainer::init_params(&p, 0).unwrap();
        let (x1, _) = p.local_train(&x0, None, &mut fleet[3], &data, 0.1, 0.0).unwrap();
        // Device 3's own objective must decrease.
        let f_dev = |x: &[f32]| -> f64 {
            (0..p.dim)
                .map(|j| {
                    0.5 * p.curvatures[3][j] as f64 * ((x[j] - p.centers[3][j]) as f64).powi(2)
                })
                .sum()
        };
        assert!(f_dev(&x1) < f_dev(&x0));
    }

    #[test]
    fn prox_anchoring_limits_drift() {
        let p = problem(0.0);
        let data = dummy_dataset();
        let mut fleet = dummy_fleet(2, 2);
        let anchor = Trainer::init_params(&p, 0).unwrap();
        let (free, _) = p.local_train(&anchor, None, &mut fleet[1], &data, 0.2, 0.0).unwrap();
        let (prox, _) = p
            .local_train(&anchor, Some(&anchor), &mut fleet[1], &data, 0.2, 5.0)
            .unwrap();
        let dist = |x: &[f32]| -> f64 {
            x.iter()
                .zip(&anchor)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        assert!(dist(&prox) < dist(&free));
    }

    #[test]
    fn beta_formulas() {
        // α→1 ⇒ β = (1−γμ)^H; α→0 ⇒ β→1.
        assert!((beta_theorem1(1.0, 0.1, 1.0, 3) - 0.9f64.powi(3)).abs() < 1e-12);
        assert!((beta_theorem1(1e-9, 0.1, 1.0, 3) - 1.0).abs() < 1e-6);
        // Theorem 2 reduces toward 1 as ρ→μ.
        let b = beta_theorem2(0.5, 0.1, 1.0 + 1e-9, 1.0, 5);
        assert!((b - 1.0).abs() < 1e-6);
    }

    #[test]
    fn weakly_convex_ripple_changes_objective() {
        let base = problem(0.0);
        let f0 = base.global_f(&vec![0.0; 8]);
        let wc = WeaklyConvexProblem::new(problem(0.0), 0.2);
        let f1 = wc.global_f(&vec![0.0; 8]);
        assert!((f1 - f0 - 0.2 * 8.0).abs() < 1e-9); // cos(0)=1 per dim
    }

    #[test]
    fn approx_f_star_below_quadratic_center_value() {
        let wc = WeaklyConvexProblem::new(problem(0.0), 0.05);
        let xs = wc.base.x_star();
        assert!(wc.approx_f_star() <= wc.global_f(&xs) + 1e-9);
    }

    #[test]
    fn evaluate_reports_gap_as_loss() {
        let p = problem(0.0);
        let xs = p.x_star();
        let m = p.evaluate(&xs, &dummy_dataset()).unwrap();
        assert!(m.loss < 1e-9);
        assert!((m.accuracy - 1.0).abs() < 1e-9);
    }
}
