//! Closed-form optimization problems implementing [`Trainer`].
//!
//! The paper's convergence guarantees (Theorems 1–2) are statements about
//! `E[F(x_T) − F(x*)]`.  On a neural network that gap is unobservable, so
//! we validate the theory on problems where it is exact:
//!
//! * [`QuadraticProblem`] — each device holds
//!   `F_i(x) = ½·(x−c_i)ᵀ·D_i·(x−c_i)` with diagonal curvatures
//!   `D_i ∈ [μ, L]` and distinct centers `c_i` (the non-IID-ness).  The
//!   global `F = (1/n)·Σ F_i` is L-smooth and μ-strongly convex with a
//!   closed-form minimizer — Theorem 1 territory.
//! * [`WeaklyConvexProblem`] — the quadratic plus a `w·Σ_j cos(x_j)`
//!   ripple, which is `w`-weakly convex (Definition 3): non-convex but
//!   `F(x) + w/2·‖x‖²` convex.  Theorem 2 territory (Option II).
//!
//! Both run through the *same* coordinator code as the PJRT model, so the
//! theory checks also exercise the production control path.
//!
//! ## Compute-plane layout (DESIGN.md §"Compute plane")
//!
//! These trainers *are* the simulated fleet's compute plane, so their hot
//! path is built for throughput:
//!
//! * **SoA storage** — `centers`/`curvatures` are contiguous row-major
//!   `n × dim` `Vec<f32>`s; a device's task streams its row once per
//!   local iteration instead of chasing `Vec<Vec<_>>` pointers.
//! * **Fused kernel** — gradient, noise, prox anchoring and the SGD step
//!   are one pass over `dim` with the *same per-element FP op order* as
//!   the original scalar two-pass loop, so results are bit-identical
//!   (property-pinned below) and the pinned golden trace never moves.
//! * **Hoisted loss** — the reported training loss only needs the final
//!   iterate, so the objective is evaluated once per task, not once per
//!   local iteration, and through [`QuadraticProblem::global_f_fast`] —
//!   an O(dim) closed form over precomputed per-coordinate moments
//!   `Σᵢdᵢⱼ`, `Σᵢdᵢⱼcᵢⱼ`, `Σᵢdᵢⱼcᵢⱼ²` (the exact O(n·dim) loop stays as
//!   [`QuadraticProblem::global_f`], property-tested against it).
//! * **Zero allocation** — all working state (the returned model buffer,
//!   gradient accumulator, batched noise draws) comes from the caller's
//!   [`TaskScratch`]; `rust/tests/alloc_regression.rs` pins 0 allocs per
//!   task in the sequential driver's steady state.

use std::cell::{OnceCell, RefCell};

use crate::coordinator::{TaskScratch, Trainer};
use crate::federated::data::Dataset;
use crate::federated::device::SimDevice;
use crate::runtime::{EvalMetrics, ParamVec, RuntimeError};
use crate::util::kernels;
use crate::util::rng::Rng;

/// Strongly convex per-device quadratics with a shared closed form.
pub struct QuadraticProblem {
    pub dim: usize,
    /// Device count n.
    n: usize,
    /// Row-major `n × dim` device centers (device i's row is
    /// `centers[i*dim .. (i+1)*dim]`).
    centers: Vec<f32>,
    /// Row-major `n × dim` diagonal curvatures, in `[mu, l]`.
    curvatures: Vec<f32>,
    /// Per-coordinate moment `Σᵢ dᵢⱼ` for the O(dim) evaluator.
    m_d: Vec<f64>,
    /// Per-coordinate moment `Σᵢ dᵢⱼ·cᵢⱼ`.
    m_dc: Vec<f64>,
    /// Per-coordinate moment `Σᵢ dᵢⱼ·cᵢⱼ²`.
    m_dcc: Vec<f64>,
    /// Std-dev of the additive gradient noise (≈ √V1).
    pub noise_std: f64,
    /// Local iterations per task (H).
    pub h: usize,
    /// Closed-form global minimizer and value.
    x_star: Vec<f64>,
    f_star: f64,
    pub mu: f64,
    pub l: f64,
    rng: RefCell<Rng>,
    init_scale: f64,
}

impl QuadraticProblem {
    /// Build a problem with `n` devices in `dim` dimensions, curvature
    /// range `[mu, l]`, center spread `spread`, gradient noise `noise_std`.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        n: usize,
        dim: usize,
        mu: f64,
        l: f64,
        spread: f64,
        noise_std: f64,
        h: usize,
        seed: u64,
    ) -> QuadraticProblem {
        assert!(mu > 0.0 && l >= mu);
        let mut rng = Rng::seed_from(seed ^ 0x0BAD_F00D);
        // Row-major fill in the same draw order as the seed's
        // row-of-rows construction, so seeded problems are unchanged.
        let centers: Vec<f32> = (0..n * dim).map(|_| (rng.gaussian() * spread) as f32).collect();
        let curvatures: Vec<f32> = (0..n * dim).map(|_| rng.uniform(mu, l) as f32).collect();
        // Per-coordinate moments: F(x) = (1/2n)·Σⱼ (Aⱼ·xⱼ² − 2·Bⱼ·xⱼ + Cⱼ)
        // with Aⱼ = Σᵢdᵢⱼ, Bⱼ = Σᵢdᵢⱼcᵢⱼ, Cⱼ = Σᵢdᵢⱼcᵢⱼ².
        let mut m_d = vec![0.0f64; dim];
        let mut m_dc = vec![0.0f64; dim];
        let mut m_dcc = vec![0.0f64; dim];
        for i in 0..n {
            let row = i * dim;
            // Per-coordinate accumulators, so the chunked kernel is
            // bitwise identical to the seed's row-major scalar loop.
            kernels::moment_accum(
                &mut m_d,
                &mut m_dc,
                &mut m_dcc,
                &centers[row..row + dim],
                &curvatures[row..row + dim],
            );
        }
        // x*_j = (Σ_i d_ij·c_ij) / (Σ_i d_ij); F* = F(x*).
        let x_star: Vec<f64> = (0..dim).map(|j| m_dc[j] / m_d[j]).collect();
        let mut problem = QuadraticProblem {
            dim,
            n,
            centers,
            curvatures,
            m_d,
            m_dc,
            m_dcc,
            noise_std,
            h,
            x_star,
            f_star: 0.0,
            mu,
            l,
            rng: RefCell::new(rng),
            init_scale: spread.max(1.0) * 2.0,
        };
        let xs: Vec<f32> = problem.x_star.iter().map(|&v| v as f32).collect();
        // f_star through the *fast* evaluator: `gap` subtracts it from
        // fast evaluations, so the gap at x* is exactly zero.
        problem.f_star = problem.global_f_fast(&xs);
        problem
    }

    /// Device count n.
    pub fn devices(&self) -> usize {
        self.n
    }

    /// Center `c_ij` (row-major lookup).
    #[inline]
    pub(crate) fn center(&self, i: usize, j: usize) -> f32 {
        self.centers[i * self.dim + j]
    }

    /// Curvature `d_ij` (row-major lookup).
    #[inline]
    pub(crate) fn curv(&self, i: usize, j: usize) -> f32 {
        self.curvatures[i * self.dim + j]
    }

    /// Global objective `F(x)` — the exact O(n·dim) reference loop.
    ///
    /// Kept as the ground truth the O(dim) [`QuadraticProblem::global_f_fast`]
    /// is property-tested against; hot paths (per-task loss, eval-grid
    /// rows, benches) use the fast form.
    pub fn global_f(&self, x: &[f32]) -> f64 {
        let mut total = 0.0f64;
        for i in 0..self.n {
            let row = i * self.dim;
            for j in 0..self.dim {
                let d = (x[j] - self.centers[row + j]) as f64;
                total += 0.5 * self.curvatures[row + j] as f64 * d * d;
            }
        }
        total / self.n as f64
    }

    /// O(dim) closed-form objective from the precomputed per-coordinate
    /// moments: `F(x) = (1/2n)·Σⱼ (Aⱼxⱼ² − 2Bⱼxⱼ + Cⱼ)`.
    ///
    /// Within ~1e-7 relative of [`QuadraticProblem::global_f`] (the only
    /// difference is the f32 `x−c` subtraction the exact loop performs);
    /// `rust/tests/proptests.rs` pins the 1e-6 bound.  The Σ over
    /// coordinates goes through [`kernels::moment_eval`]: under the
    /// default `fast-kernels` feature that reduction is reassociated
    /// across lanes (≤ 1e-6 relative of the serial order — the one
    /// tolerance-banded kernel; everything else on the hot path is
    /// bitwise).
    pub fn global_f_fast(&self, x: &[f32]) -> f64 {
        let total = kernels::moment_eval(x, &self.m_d, &self.m_dc, &self.m_dcc);
        0.5 * total / self.n as f64
    }

    /// Optimality gap `F(x) − F(x*)` (the quantity in Theorems 1–2),
    /// via the O(dim) evaluator (both terms, so the gap at `x*` is 0).
    pub fn gap(&self, x: &[f32]) -> f64 {
        (self.global_f_fast(x) - self.f_star).max(0.0)
    }

    pub fn x_star(&self) -> Vec<f32> {
        self.x_star.iter().map(|&v| v as f32).collect()
    }

    /// The one fused local-SGD kernel both closed-form trainers run: H
    /// iterations of gradient + optional cosine-ripple term + noise +
    /// prox + step, each a single pass over `dim` with the seed scalar
    /// path's per-element FP op order (property-pinned below).
    ///
    /// `ripple = Some(w)` inserts the weakly-convex problem's
    /// `−w·sin(x_j)` gradient addend between the quadratic gradient and
    /// the noise, exactly where the seed placed it; `None` skips the op
    /// entirely so the pure quadratic's sequence is untouched.  Keeping
    /// the op sequence in one function is what lets one bitwise property
    /// cover both trainers.
    ///
    /// The loop bodies live in [`kernels`]: the `fast-kernels` feature
    /// (default) selects the lane-chunked variants — plus the H-tiled
    /// single-memory-pass path when noise and ripple are both off — all
    /// of which preserve the per-element op order and therefore the
    /// bit-exact trajectory; `--no-default-features` selects the scalar
    /// references.  The replay property below pins whichever is selected
    /// against the seed path, bitwise.
    fn fused_local_train(
        &self,
        params: &[f32],
        anchor: Option<&[f32]>,
        device_id: usize,
        gamma: f32,
        rho: f32,
        ripple: Option<f64>,
        scratch: &mut TaskScratch,
    ) -> ParamVec {
        let centralized = device_id == crate::coordinator::sgd::CENTRALIZED_DEVICE;
        let mut x = scratch.acquire(self.dim);
        x.extend_from_slice(params);
        let mut rng = self.rng.borrow_mut();
        if centralized {
            // The centralized SGD baseline sees the *global* objective:
            // accumulate the device-mean gradient row-major (the same
            // per-coordinate f64 add order as summing device-by-device),
            // then take the fused noise/step pass.
            for _ in 0..self.h {
                let (g, noise) = scratch.grad_and_noise(self.dim);
                for k in 0..self.n {
                    let row = k * self.dim;
                    kernels::grad_accum(
                        g,
                        &x,
                        &self.centers[row..row + self.dim],
                        &self.curvatures[row..row + self.dim],
                    );
                }
                if self.noise_std > 0.0 {
                    rng.fill_gaussian(noise);
                }
                let n_f = self.n as f64;
                kernels::central_step(
                    &mut x,
                    g,
                    n_f,
                    noise,
                    self.noise_std,
                    ripple,
                    anchor,
                    rho,
                    gamma,
                );
            }
        } else {
            // One contiguous row per device (SoA): stream it with unit
            // stride once per local iteration.
            let i = device_id % self.n;
            let row = i * self.dim;
            let cen = &self.centers[row..row + self.dim];
            let cur = &self.curvatures[row..row + self.dim];
            if cfg!(feature = "fast-kernels") && self.noise_std == 0.0 && ripple.is_none() {
                // No per-iteration RNG draws and no `sin` ⇒ the H local
                // iterations can run register-tiled: one memory pass over
                // the row instead of H, bitwise identical to the
                // per-iteration loop below (each coordinate's op
                // sequence is unchanged; kernels.rs pins it).
                kernels::quad_train_tiled(&mut x, cen, cur, anchor, rho, gamma, self.h);
            } else {
                for _ in 0..self.h {
                    let noise = scratch.noise(self.dim);
                    if self.noise_std > 0.0 {
                        rng.fill_gaussian(noise);
                    }
                    kernels::quad_step(
                        &mut x,
                        cen,
                        cur,
                        noise,
                        self.noise_std,
                        ripple,
                        anchor,
                        rho,
                        gamma,
                    );
                }
            }
        }
        x
    }
}

impl Trainer for QuadraticProblem {
    fn param_count(&self) -> usize {
        self.dim
    }

    fn init_params(&self, seed_idx: usize) -> Result<ParamVec, RuntimeError> {
        let mut rng = Rng::seed_from(0x1217 + seed_idx as u64);
        Ok((0..self.dim)
            .map(|_| (rng.gaussian() * self.init_scale) as f32)
            .collect())
    }

    fn local_train(
        &self,
        params: &[f32],
        anchor: Option<&[f32]>,
        device: &mut SimDevice,
        _data: &Dataset,
        gamma: f32,
        rho: f32,
        scratch: &mut TaskScratch,
    ) -> Result<(ParamVec, f32), RuntimeError> {
        let x = self.fused_local_train(params, anchor, device.id, gamma, rho, None, scratch);
        // Only the final iterate's objective is reported, so evaluate it
        // once, after the H-loop, through the O(dim) closed form — the
        // seed recomputed the O(n·dim) objective inside every iteration.
        let f = self.global_f_fast(&x);
        Ok((x, f as f32))
    }

    fn evaluate(&self, params: &[f32], _test: &Dataset) -> Result<EvalMetrics, RuntimeError> {
        let gap = self.gap(params);
        Ok(EvalMetrics {
            loss: gap,
            // Monotone proxy so "accuracy" plots still slope the right way.
            accuracy: 1.0 / (1.0 + gap),
            samples: 1,
        })
    }

    fn local_iters(&self) -> usize {
        self.h
    }
}

/// Quadratic + `w·Σ cos(x_j)`: `w`-weakly convex (paper Definition 3).
pub struct WeaklyConvexProblem {
    pub base: QuadraticProblem,
    /// Weak-convexity modulus `w` (= μ in Definition 3).
    pub w: f64,
    /// Lazily computed (then cached) approximate optimum — evaluation
    /// used to redo the 2000-step descent on every eval-grid row.
    f_star_cache: OnceCell<f64>,
}

impl WeaklyConvexProblem {
    pub fn new(base: QuadraticProblem, w: f64) -> WeaklyConvexProblem {
        assert!(w >= 0.0);
        WeaklyConvexProblem { base, w, f_star_cache: OnceCell::new() }
    }

    /// Exact objective (reference loop + ripple).
    pub fn global_f(&self, x: &[f32]) -> f64 {
        self.base.global_f(x) + self.ripple(x)
    }

    /// O(dim) objective: the base's moment closed form + ripple.
    pub fn global_f_fast(&self, x: &[f32]) -> f64 {
        self.base.global_f_fast(x) + self.ripple(x)
    }

    fn ripple(&self, x: &[f32]) -> f64 {
        self.w * x.iter().map(|&v| (v as f64).cos()).sum::<f64>()
    }

    /// Numerically locate the global optimum near the quadratic minimizer
    /// (valid when `w ≪ μ·spread`: the ripple only shifts the basin).
    /// Computed once and cached — the descent itself is O(dim) per step
    /// via the base moments.
    pub fn approx_f_star(&self) -> f64 {
        *self.f_star_cache.get_or_init(|| {
            let mut x = self.base.x_star();
            // Deterministic gradient descent on the true F (no noise);
            // mean base gradient = (Aⱼ·xⱼ − Bⱼ)/n via the moments.
            let n_f = self.base.n as f64;
            for _ in 0..2000 {
                for j in 0..x.len() {
                    let g = (self.base.m_d[j] * x[j] as f64 - self.base.m_dc[j]) / n_f
                        - self.w * (x[j] as f64).sin();
                    x[j] -= 0.1 * g as f32;
                }
            }
            self.global_f_fast(&x)
        })
    }
}

impl Trainer for WeaklyConvexProblem {
    fn param_count(&self) -> usize {
        self.base.dim
    }

    fn init_params(&self, seed_idx: usize) -> Result<ParamVec, RuntimeError> {
        self.base.init_params(seed_idx)
    }

    fn local_train(
        &self,
        params: &[f32],
        anchor: Option<&[f32]>,
        device: &mut SimDevice,
        _data: &Dataset,
        gamma: f32,
        rho: f32,
        scratch: &mut TaskScratch,
    ) -> Result<(ParamVec, f32), RuntimeError> {
        let w = Some(self.w);
        let x = self.base.fused_local_train(params, anchor, device.id, gamma, rho, w, scratch);
        let f = self.global_f_fast(&x);
        Ok((x, f as f32))
    }

    fn evaluate(&self, params: &[f32], _test: &Dataset) -> Result<EvalMetrics, RuntimeError> {
        let gap = (self.global_f_fast(params) - self.approx_f_star()).max(0.0);
        Ok(EvalMetrics { loss: gap, accuracy: 1.0 / (1.0 + gap), samples: 1 })
    }

    fn local_iters(&self) -> usize {
        self.base.h
    }
}

/// Theorem 1's contraction factor `β = 1 − α + α(1 − γμ)^{H_min}`.
pub fn beta_theorem1(alpha: f64, gamma: f64, mu: f64, h_min: usize) -> f64 {
    1.0 - alpha + alpha * (1.0 - gamma * mu).powi(h_min as i32)
}

/// Theorem 2's contraction factor `β = 1 − α + α(1 − γ(ρ−μ)/2)^{H_min}`.
pub fn beta_theorem2(alpha: f64, gamma: f64, rho: f64, mu: f64, h_min: usize) -> f64 {
    1.0 - alpha + alpha * (1.0 - gamma * (rho - mu) / 2.0).powi(h_min as i32)
}

/// Dummy dataset/fleet pieces so closed-form problems can reuse the
/// federated coordinators (which thread `&Dataset` and `&mut SimDevice`
/// through to the trainer).
pub fn dummy_dataset() -> Dataset {
    Dataset { features: vec![0.0; 4], labels: vec![0], input_size: 4, num_classes: 10 }
}

/// Fleet of `n` trivial devices (id is all the quadratic trainer reads).
pub fn dummy_fleet(n: usize, seed: u64) -> Vec<SimDevice> {
    use crate::federated::device::AvailabilityModel;
    let mut rng = Rng::seed_from(seed);
    (0..n)
        .map(|id| {
            SimDevice::new(
                id,
                vec![0],
                1.0,
                AvailabilityModel { mean_up: 1e18, mean_down: 1e-9 },
                rng.split(),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sgd::CENTRALIZED_DEVICE;
    use crate::federated::device::AvailabilityModel;
    use crate::prop_ensure;
    use crate::util::prop::check;

    fn problem(noise: f64) -> QuadraticProblem {
        QuadraticProblem::new(10, 8, 0.5, 2.0, 3.0, noise, 5, 42)
    }

    /// The seed's scalar AoS path, verbatim: two passes per local
    /// iteration (`device_grad` into `g`, then noise/prox/step, with the
    /// weakly-convex `−w·sin` term between them when `ripple` is set),
    /// loss = exact `global_f` of the final iterate (+ ripple).  The
    /// fused SoA kernel must reproduce the trajectory bit-for-bit for
    /// both trainers.
    fn seed_scalar_local_train(
        p: &QuadraticProblem,
        params: &[f32],
        anchor: Option<&[f32]>,
        device: usize,
        gamma: f32,
        rho: f32,
        ripple: Option<f64>,
    ) -> (Vec<f32>, f64) {
        let mut x: Vec<f32> = params.to_vec();
        let mut g = vec![0.0f64; p.dim];
        let mut rng = p.rng.borrow_mut();
        for _ in 0..p.h {
            if device == CENTRALIZED_DEVICE {
                for j in 0..p.dim {
                    g[j] = (0..p.n)
                        .map(|i| p.curv(i, j) as f64 * (x[j] - p.center(i, j)) as f64)
                        .sum::<f64>()
                        / p.n as f64;
                }
            } else {
                let i = device % p.n;
                for j in 0..p.dim {
                    g[j] = p.curv(i, j) as f64 * (x[j] - p.center(i, j)) as f64;
                }
            }
            for j in 0..p.dim {
                let noise = if p.noise_std > 0.0 {
                    rng.gaussian() * p.noise_std
                } else {
                    0.0
                };
                let mut gj = g[j];
                if let Some(w) = ripple {
                    gj -= w * (x[j] as f64).sin();
                }
                gj += noise;
                if let Some(a) = anchor {
                    gj += rho as f64 * (x[j] - a[j]) as f64;
                }
                x[j] -= gamma * gj as f32;
            }
        }
        drop(rng);
        let cos_sum = x.iter().map(|&v| (v as f64).cos()).sum::<f64>();
        let last_f = p.global_f(&x) + ripple.map_or(0.0, |w| w * cos_sum);
        (x, last_f)
    }

    #[test]
    fn prop_fused_soa_local_train_bitwise_matches_seed_scalar_path() {
        check("fused-matches-seed-scalar", 60, |g| {
            let n = g.size(1, 8);
            let dim = g.size(1, 24);
            let h = g.size(1, 6);
            let noise = if g.bool() { 0.0 } else { 0.05 };
            // Half the cases run the weakly-convex ripple path, so both
            // trainers' op sequences are pinned by the one property.
            let ripple = g.bool().then(|| g.f64_in(0.0, 0.3));
            let seed = g.rng.next_u64();
            // Two identical problems: construction consumes the same
            // draws, so their RNGs are in lockstep afterwards.
            let fused = QuadraticProblem::new(n, dim, 0.5, 2.0, 2.0, noise, h, seed);
            let reference = QuadraticProblem::new(n, dim, 0.5, 2.0, 2.0, noise, h, seed);
            let data = dummy_dataset();
            let device = match g.index(4) {
                0 => CENTRALIZED_DEVICE,
                _ => g.index(n + 2), // exercises the `id % n` wrap too
            };
            let mut dev = SimDevice::new(
                device,
                vec![0],
                1.0,
                AvailabilityModel { mean_up: 1e18, mean_down: 1e-9 },
                Rng::seed_from(1),
            );
            let x0 = Trainer::init_params(&fused, 0).map_err(|e| e.to_string())?;
            let (use_prox, rho) = if g.bool() {
                (true, 1.5f32)
            } else {
                (false, 0.0f32)
            };
            let anchor = use_prox.then(|| x0.as_slice());
            let mut scratch = TaskScratch::new();
            let (got, got_loss) = match ripple {
                None => fused
                    .local_train(&x0, anchor, &mut dev, &data, 0.1, rho, &mut scratch)
                    .map_err(|e| e.to_string())?,
                Some(w) => WeaklyConvexProblem::new(fused, w)
                    .local_train(&x0, anchor, &mut dev, &data, 0.1, rho, &mut scratch)
                    .map_err(|e| e.to_string())?,
            };
            let (want, want_loss) =
                seed_scalar_local_train(&reference, &x0, anchor, device, 0.1, rho, ripple);
            prop_ensure!(
                got == want,
                "trajectory drifted (n={n} dim={dim} h={h} noise={noise} prox={use_prox} \
                 ripple={ripple:?} dev={device})"
            );
            // Loss goes through the O(dim) evaluator — not bitwise, but
            // within the evaluator's pinned tolerance of the exact loop.
            let denom = want_loss.abs().max(1e-9);
            prop_ensure!(
                ((got_loss as f64 - want_loss) / denom).abs() < 1e-5,
                "loss drifted: fast {got_loss} vs exact {want_loss}"
            );
            Ok(())
        });
    }

    #[test]
    fn x_star_is_a_stationary_point() {
        let p = problem(0.0);
        let xs = p.x_star();
        // Mean gradient at x* must vanish.
        for j in 0..p.dim {
            let g: f64 = (0..p.n)
                .map(|i| p.curv(i, j) as f64 * (xs[j] - p.center(i, j)) as f64)
                .sum::<f64>()
                / p.n as f64;
            assert!(g.abs() < 1e-5, "grad[{j}]={g}");
        }
        assert!(p.gap(&xs) < 1e-9);
    }

    #[test]
    fn gap_is_positive_away_from_optimum() {
        let p = problem(0.0);
        let mut x = p.x_star();
        x[0] += 1.0;
        assert!(p.gap(&x) > 0.1);
    }

    #[test]
    fn local_train_descends_device_objective() {
        let p = problem(0.0);
        let data = dummy_dataset();
        let mut fleet = dummy_fleet(4, 1);
        let mut scratch = TaskScratch::new();
        let x0 = Trainer::init_params(&p, 0).unwrap();
        let (x1, _) = p
            .local_train(&x0, None, &mut fleet[3], &data, 0.1, 0.0, &mut scratch)
            .unwrap();
        // Device 3's own objective must decrease.
        let f_dev = |x: &[f32]| -> f64 {
            (0..p.dim)
                .map(|j| 0.5 * p.curv(3, j) as f64 * ((x[j] - p.center(3, j)) as f64).powi(2))
                .sum()
        };
        assert!(f_dev(&x1) < f_dev(&x0));
    }

    #[test]
    fn prox_anchoring_limits_drift() {
        let p = problem(0.0);
        let data = dummy_dataset();
        let mut fleet = dummy_fleet(2, 2);
        let mut scratch = TaskScratch::new();
        let anchor = Trainer::init_params(&p, 0).unwrap();
        let (free, _) = p
            .local_train(&anchor, None, &mut fleet[1], &data, 0.2, 0.0, &mut scratch)
            .unwrap();
        let (prox, _) = p
            .local_train(&anchor, Some(&anchor), &mut fleet[1], &data, 0.2, 5.0, &mut scratch)
            .unwrap();
        let dist = |x: &[f32]| -> f64 {
            x.iter()
                .zip(&anchor)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        assert!(dist(&prox) < dist(&free));
    }

    #[test]
    fn local_train_reuses_released_buffers() {
        // The returned model buffer must cycle through the scratch: after
        // release, the next task gets the same allocation back.
        let p = problem(0.1);
        let data = dummy_dataset();
        let mut fleet = dummy_fleet(2, 3);
        let mut scratch = TaskScratch::new();
        let x0 = Trainer::init_params(&p, 0).unwrap();
        let (x1, _) = p
            .local_train(&x0, None, &mut fleet[0], &data, 0.1, 0.0, &mut scratch)
            .unwrap();
        let ptr = x1.as_ptr();
        scratch.release(x1);
        let (x2, _) = p
            .local_train(&x0, None, &mut fleet[1], &data, 0.1, 0.0, &mut scratch)
            .unwrap();
        assert_eq!(x2.as_ptr(), ptr, "second task did not reuse the released buffer");
    }

    #[test]
    fn beta_formulas() {
        // α→1 ⇒ β = (1−γμ)^H; α→0 ⇒ β→1.
        assert!((beta_theorem1(1.0, 0.1, 1.0, 3) - 0.9f64.powi(3)).abs() < 1e-12);
        assert!((beta_theorem1(1e-9, 0.1, 1.0, 3) - 1.0).abs() < 1e-6);
        // Theorem 2 reduces toward 1 as ρ→μ.
        let b = beta_theorem2(0.5, 0.1, 1.0 + 1e-9, 1.0, 5);
        assert!((b - 1.0).abs() < 1e-6);
    }

    #[test]
    fn weakly_convex_ripple_changes_objective() {
        let base = problem(0.0);
        let f0 = base.global_f(&vec![0.0; 8]);
        let wc = WeaklyConvexProblem::new(problem(0.0), 0.2);
        let f1 = wc.global_f(&vec![0.0; 8]);
        assert!((f1 - f0 - 0.2 * 8.0).abs() < 1e-9); // cos(0)=1 per dim
    }

    #[test]
    fn approx_f_star_below_quadratic_center_value() {
        let wc = WeaklyConvexProblem::new(problem(0.0), 0.05);
        let xs = wc.base.x_star();
        assert!(wc.approx_f_star() <= wc.global_f(&xs) + 1e-9);
    }

    #[test]
    fn weakly_convex_fast_matches_exact() {
        let wc = WeaklyConvexProblem::new(problem(0.0), 0.1);
        let mut x = wc.base.x_star();
        x.iter_mut().for_each(|v| *v += 0.3);
        let exact = wc.global_f(&x);
        let fast = wc.global_f_fast(&x);
        assert!(
            (fast - exact).abs() <= 1e-6 * exact.abs().max(1e-12),
            "exact {exact} vs fast {fast}"
        );
    }

    #[test]
    fn evaluate_reports_gap_as_loss() {
        let p = problem(0.0);
        let xs = p.x_star();
        let m = p.evaluate(&xs, &dummy_dataset()).unwrap();
        assert!(m.loss < 1e-9);
        assert!((m.accuracy - 1.0).abs() < 1e-9);
    }
}
