//! The pre-SoA, struct-of-vecs scenario behavior, kept as the
//! **reference model** for property tests.
//!
//! [`ReferenceScenarioBehavior`] is the original per-client
//! implementation that [`super::ScenarioBehavior`] replaced when the
//! fleet state was compacted into SoA arrays: it stores whole
//! [`SpeedTier`] structs, `Vec<usize>` assignments, and one `Vec<bool>`
//! per straggler burst.  Nothing in the simulator uses it; it exists so
//! `rust/tests/proptests.rs` can assert — draw for draw, bit for bit —
//! that the compact representation makes the *same* latency, churn,
//! straggler, staleness, and delivery decisions from the same seed.
//!
//! The compile-time RNG protocol (one shuffle for tier dealing, one for
//! churn ranks, one `choose_k` per burst, all from `seed ^ 0x5CE4_4210`)
//! and the query-time draw counts are the pinned contract; any edit here
//! must be mirrored in `behavior.rs` and vice versa.

use super::{ClientBehavior, Delivery, ScenarioConfig, SpeedTier};
use crate::util::rng::Rng;

/// A [`ScenarioConfig`] compiled for a concrete fleet with per-client
/// heap structures (the original layout).  See the module docs: this is
/// the property-test oracle for [`super::ScenarioBehavior`].
pub struct ReferenceScenarioBehavior {
    name: String,
    n: usize,
    tiers: Vec<SpeedTier>,
    /// Tier index per device.
    tier_of: Vec<usize>,
    /// Devices with `churn_rank < present_count(p)` are present at `p`.
    churn_rank: Vec<usize>,
    churn: Vec<super::ChurnPhase>,
    /// `(burst, member?)` per configured burst.
    bursts: Vec<(super::StragglerBurst, Vec<bool>)>,
    faults: super::FaultModel,
}

impl ReferenceScenarioBehavior {
    /// Compile `sc` for a fleet of `devices`, drawing every per-device
    /// assignment deterministically from `seed` — the identical protocol
    /// [`super::ScenarioBehavior::new`] pins itself to.
    pub fn new(sc: &ScenarioConfig, devices: usize, seed: u64) -> ReferenceScenarioBehavior {
        assert!(devices > 0, "scenario behavior needs a non-empty fleet");
        let n = devices;
        let mut rng = Rng::seed_from(seed ^ 0x5CE4_4210);

        // Normalize tiers (empty = single nominal tier) and deal devices
        // into them in a seeded random order.
        let tiers: Vec<SpeedTier> = if sc.tiers.is_empty() {
            vec![SpeedTier::nominal()]
        } else {
            let total: f64 = sc.tiers.iter().map(|t| t.fraction).sum();
            sc.tiers
                .iter()
                .map(|t| SpeedTier { fraction: t.fraction / total, ..t.clone() })
                .collect()
        };
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut tier_of = vec![0usize; n];
        let mut acc = 0.0f64;
        let mut start = 0usize;
        for (ti, t) in tiers.iter().enumerate() {
            acc += t.fraction;
            let end = if ti + 1 == tiers.len() {
                n
            } else {
                ((acc * n as f64).round() as usize).min(n)
            };
            for &d in &order[start..end.max(start)] {
                tier_of[d] = ti;
            }
            start = end.max(start);
        }

        // Churn ranks: an independent shuffle decides who leaves first.
        let mut churn_order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut churn_order);
        let mut churn_rank = vec![0usize; n];
        for (rank, &d) in churn_order.iter().enumerate() {
            churn_rank[d] = rank;
        }

        // Burst membership: an independent draw per burst.
        let bursts = sc
            .bursts
            .iter()
            .map(|b| {
                let k = ((b.fraction * n as f64).ceil() as usize).clamp(1, n);
                let mut member = vec![false; n];
                for d in rng.choose_k(n, k) {
                    member[d] = true;
                }
                (*b, member)
            })
            .collect();

        ReferenceScenarioBehavior {
            name: sc.name.clone(),
            n,
            tiers,
            tier_of,
            churn_rank,
            churn: sc.churn.clone(),
            bursts,
            faults: sc.faults,
        }
    }

    /// Present fraction of the fleet at progress `p` (last phase at or
    /// before `p` wins; 1.0 before the first phase).
    fn present_level(&self, progress: f64) -> f64 {
        let mut level = 1.0;
        for c in &self.churn {
            if c.at <= progress {
                level = c.present;
            } else {
                break;
            }
        }
        level
    }

    fn tier(&self, device: usize) -> &SpeedTier {
        &self.tiers[self.tier_of[device.min(self.n - 1)]]
    }
}

impl ClientBehavior for ReferenceScenarioBehavior {
    fn label(&self) -> String {
        self.name.clone()
    }

    fn is_present(&self, device: usize, progress: f64) -> bool {
        self.churn_rank[device.min(self.n - 1)] < self.present_count(progress)
    }

    fn present_count(&self, progress: f64) -> usize {
        ((self.present_level(progress) * self.n as f64).ceil() as usize).clamp(1, self.n)
    }

    fn slowdown(&self, device: usize, progress: f64) -> f64 {
        let mut s = 1.0 / self.tier(device).speed;
        for (b, member) in &self.bursts {
            if member[device.min(self.n - 1)] && progress >= b.from && progress < b.until {
                s *= b.slowdown;
            }
        }
        s
    }

    fn link_latency(&self, device: usize, rng: &mut Rng) -> f64 {
        let t = self.tier(device);
        rng.lognormal(t.latency_mu, t.latency_sigma)
    }

    fn sample_staleness(&self, device: usize, progress: f64, max: u64, rng: &mut Rng) -> u64 {
        // Uniform draw reshaped by the device's slowdown — identical
        // formula and draw count to the SoA path.
        let max = max.max(1);
        let sl = self.slowdown(device, progress).max(1e-6);
        let u = rng.f64().powf(1.0 / sl);
        (1 + (u * max as f64).floor() as u64).min(max)
    }

    fn delivery(&self, _device: usize, _progress: f64, rng: &mut Rng) -> Delivery {
        let f = &self.faults;
        if f.drop_prob <= 0.0 && f.duplicate_prob <= 0.0 {
            return Delivery::Deliver;
        }
        let u = rng.f64();
        if u < f.drop_prob {
            Delivery::Drop
        } else if u < f.drop_prob + f.duplicate_prob {
            Delivery::Duplicate
        } else {
            Delivery::Deliver
        }
    }
}
