//! Named scenario presets: the library of client populations shipped with
//! the repo (selectable as `scenario = "<name>"` in TOML or
//! `--scenario <name>` on the CLI; `configs/scenario_*.toml` carry full
//! experiment configs around three of them).

use super::{ChurnPhase, FaultModel, ScenarioConfig, SpeedTier, StragglerBurst};

/// Resolve a preset by name.
pub fn named(name: &str) -> Option<ScenarioConfig> {
    let mut sc = ScenarioConfig { name: name.to_string(), ..ScenarioConfig::default() };
    match name {
        // Three speed tiers (flagship / mid-range / budget devices), links
        // degrading with compute speed, a whiff of transport loss.
        "tiered_fleet" => {
            sc.tiers = vec![
                tier(0.5, 1.0),
                tier(0.3, 0.4),
                tier(0.2, 0.15),
            ];
            sc.faults = FaultModel { drop_prob: 0.02, duplicate_prob: 0.0 };
        }
        // Day/night participation: half the fleet vanishes a quarter of
        // the way in, most of it returns for the final stretch.
        "diurnal_churn" => {
            sc.churn = vec![
                ChurnPhase { at: 0.25, present: 0.5 },
                ChurnPhase { at: 0.7, present: 0.9 },
            ];
            sc.faults = FaultModel { drop_prob: 0.02, duplicate_prob: 0.0 };
        }
        // A mid-run burst turns a quarter of a two-tier fleet 8× slower,
        // with duplicate deliveries from retrying uplinks.
        "straggler_storm" => {
            sc.tiers = vec![tier(0.8, 1.0), tier(0.2, 0.5)];
            sc.bursts = vec![StragglerBurst {
                from: 0.3,
                until: 0.7,
                fraction: 0.25,
                slowdown: 8.0,
            }];
            sc.faults = FaultModel { drop_prob: 0.0, duplicate_prob: 0.05 };
        }
        // Homogeneous fleet behind an unreliable transport.
        "lossy_uplink" => {
            sc.faults = FaultModel { drop_prob: 0.15, duplicate_prob: 0.05 };
        }
        // The scale-ceiling population (`configs/scenario_million.toml`
        // runs it over a 10⁶-device fleet): heterogeneity on every axis
        // at once — four speed tiers down to 0.08× with matching link
        // degradation, a deep diurnal trough, a mid-run straggler burst,
        // and light transport faults.  Sized so the SoA behavior arrays,
        // the timer-wheel horizon, and the streaming metrics path all
        // get exercised by one scenario.
        "million_fleet" => {
            sc.tiers = vec![
                tier(0.35, 1.0),
                tier(0.35, 0.45),
                tier(0.2, 0.2),
                tier(0.1, 0.08),
            ];
            sc.churn = vec![
                ChurnPhase { at: 0.3, present: 0.6 },
                ChurnPhase { at: 0.75, present: 0.85 },
            ];
            sc.bursts = vec![StragglerBurst {
                from: 0.45,
                until: 0.6,
                fraction: 0.1,
                slowdown: 6.0,
            }];
            sc.faults = FaultModel { drop_prob: 0.01, duplicate_prob: 0.01 };
        }
        _ => return None,
    }
    Some(sc)
}

/// Tier with the default latency scaling (`mu = -3 − ln(speed)`).
fn tier(fraction: f64, speed: f64) -> SpeedTier {
    SpeedTier {
        fraction,
        speed,
        latency_mu: super::DEFAULT_LATENCY_MU - speed.ln(),
        latency_sigma: super::DEFAULT_LATENCY_SIGMA,
    }
}

/// Names [`named`] resolves, for CLI listings and error messages.
pub fn preset_names() -> &'static [&'static str] {
    &["tiered_fleet", "diurnal_churn", "straggler_storm", "lossy_uplink", "million_fleet"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_validate_and_roundtrip() {
        for name in preset_names() {
            let sc = named(name).unwrap_or_else(|| panic!("missing preset {name}"));
            sc.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            let back = ScenarioConfig::from_json(&sc.to_json())
                .unwrap_or_else(|e| panic!("{name} roundtrip: {e}"));
            assert_eq!(back, sc, "{name} did not roundtrip");
        }
    }

    #[test]
    fn unknown_preset_is_none() {
        assert!(named("nope").is_none());
    }
}
