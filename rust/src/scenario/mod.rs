//! Scenario layer: heterogeneous client populations as declarative config.
//!
//! The paper evaluates one implicit population — every device shares one
//! latency model.  Real federations are messier (Fraboni et al. 2022;
//! Chen et al. 2019): device *speed tiers*, clients *churning* in and out,
//! *straggler bursts* (a slice of the fleet suddenly k× slower), and
//! *update faults* (deliveries lost or duplicated).  A [`ScenarioConfig`]
//! composes those four axes declaratively; [`behavior::ScenarioBehavior`]
//! compiles it into one [`ClientBehavior`] object that **all three
//! execution modes** consume — the sampled-staleness protocol (shapes the
//! staleness draw), the emergent discrete-event simulator (shapes event
//! latencies), and the threaded server (shapes per-task sleeps) — so a
//! scenario means the same thing everywhere by construction, mirroring how
//! the shared `UpdaterCore` unified the update path.
//!
//! Scenario time is **run progress** `p ∈ [0, 1]` (fraction of the epoch
//! budget completed), not virtual seconds: the three modes advance time in
//! incompatible units, but all of them know how far through the run they
//! are, so schedules keyed on progress stay mode-independent.
//!
//! ## TOML keys (`[scenario]` table of an experiment config)
//!
//! ```toml
//! [scenario]
//! name = "tiered"             # label for logs/provenance
//! # Speed tiers: parallel arrays, one entry per tier.
//! tier_fraction = [0.6, 0.3, 0.1]   # share of the fleet per tier
//! tier_speed = [1.0, 0.4, 0.15]     # relative compute speed (1 = nominal)
//! tier_latency_mu = [-3.0, -2.1, -1.1]   # optional log-normal link params;
//! tier_latency_sigma = [0.8, 0.8, 1.0]   # default mu = -3 - ln(speed), sigma 0.8
//! # Churn schedule: at progress `churn_at[i]` the present fraction of the
//! # fleet becomes `churn_present[i]` (initially 1.0).
//! churn_at = [0.25, 0.6]
//! churn_present = [0.5, 0.9]
//! # Straggler bursts: within [from, until) progress, `fraction` of devices
//! # run `slowdown`× slower.
//! straggler_from = [0.4]
//! straggler_until = [0.7]
//! straggler_fraction = [0.25]
//! straggler_slowdown = [8.0]
//! # Update faults at delivery time.
//! drop_prob = 0.05
//! duplicate_prob = 0.02
//! ```
//!
//! A scenario can also be selected by preset name: `scenario = "tiered_fleet"`
//! in TOML, or `--scenario tiered_fleet` on the CLI (see [`presets`]).
//!
//! Metric output grows two scenario-facing signals: a cumulative staleness
//! histogram per run (`federated::metrics::StalenessHist`, written as
//! `<stem>.staleness.csv`) and a per-row effective-client-count column
//! (`clients` in the metrics CSV).

#![warn(missing_docs)]

pub mod behavior;
pub mod presets;
pub mod reference;

pub use behavior::{
    behavior_for, pick_present, ClientBehavior, Delivery, ScenarioBehavior, UniformBehavior,
};

use crate::config::ConfigError;
use crate::util::json::{Json, JsonObj};

/// Default log-normal link-latency μ (matches
/// `federated::network::LatencyModel::default`).
pub const DEFAULT_LATENCY_MU: f64 = -3.0;
/// Default log-normal link-latency σ.
pub const DEFAULT_LATENCY_SIGMA: f64 = 0.8;

/// One device speed tier: a share of the fleet with its own compute speed
/// and link-latency distribution.
#[derive(Debug, Clone, PartialEq)]
pub struct SpeedTier {
    /// Share of the fleet in this tier (normalized across tiers).
    pub fraction: f64,
    /// Relative compute speed (1.0 = nominal, < 1 = slower).
    pub speed: f64,
    /// Log-normal link latency `exp(N(mu, sigma))` for this tier: μ.
    pub latency_mu: f64,
    /// Log-normal link latency: σ.
    pub latency_sigma: f64,
}

impl SpeedTier {
    /// Nominal tier: speed 1, default latency model.
    pub fn nominal() -> SpeedTier {
        SpeedTier {
            fraction: 1.0,
            speed: 1.0,
            latency_mu: DEFAULT_LATENCY_MU,
            latency_sigma: DEFAULT_LATENCY_SIGMA,
        }
    }
}

/// One step of the churn schedule: from progress `at` onward, `present`
/// fraction of the fleet participates (until the next phase).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnPhase {
    /// Run progress `p` at which this phase starts.
    pub at: f64,
    /// Fraction of the fleet present from `at` onward, in `(0, 1]`.
    pub present: f64,
}

/// A straggler burst: in `[from, until)` progress, `fraction` of the fleet
/// runs `slowdown`× slower.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StragglerBurst {
    /// Burst window start (run progress).
    pub from: f64,
    /// Burst window end, exclusive (run progress).
    pub until: f64,
    /// Fraction of the fleet affected, in `(0, 1]`.
    pub fraction: f64,
    /// Multiplicative slowdown for affected devices (≥ 1).
    pub slowdown: f64,
}

/// Delivery-fault probabilities, applied when an update reaches the server.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultModel {
    /// Update lost in transit (trained, never delivered).
    pub drop_prob: f64,
    /// Update delivered twice (retry storm / at-least-once transport).
    pub duplicate_prob: f64,
}

/// Declarative description of a heterogeneous client population.
///
/// The default scenario is trivial: one nominal tier, no churn, no bursts,
/// no faults — byte-for-byte the behavior the repo had before the scenario
/// layer existed.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioConfig {
    /// Label for logs and provenance.
    pub name: String,
    /// Empty = single nominal tier.
    pub tiers: Vec<SpeedTier>,
    /// Empty = the whole fleet is always present.
    pub churn: Vec<ChurnPhase>,
    /// Straggler bursts (empty = none).
    pub bursts: Vec<StragglerBurst>,
    /// Delivery-fault probabilities.
    pub faults: FaultModel,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            name: "custom".into(),
            tiers: Vec::new(),
            churn: Vec::new(),
            bursts: Vec::new(),
            faults: FaultModel::default(),
        }
    }
}

/// Every key a `[scenario]` table may carry; anything else is a typo and
/// is rejected rather than silently ignored.
const SCENARIO_KEYS: &[&str] = &[
    "name",
    "tier_fraction",
    "tier_speed",
    "tier_latency_mu",
    "tier_latency_sigma",
    "churn_at",
    "churn_present",
    "straggler_from",
    "straggler_until",
    "straggler_fraction",
    "straggler_slowdown",
    "drop_prob",
    "duplicate_prob",
];

impl ScenarioConfig {
    /// Parse from a `[scenario]` JSON/TOML object tree.
    ///
    /// Strict by design: unknown keys and wrong-typed values are errors —
    /// a typo'd scenario must never silently degrade to the uniform
    /// baseline population while the provenance claims otherwise.
    pub fn from_json(v: &Json) -> Result<ScenarioConfig, ConfigError> {
        if let Some(obj) = v.as_obj() {
            for k in obj.keys() {
                if !SCENARIO_KEYS.contains(&k.as_str()) {
                    return Err(ConfigError(format!(
                        "scenario: unknown key {k:?} (known: {})",
                        SCENARIO_KEYS.join(", ")
                    )));
                }
            }
        }
        let mut sc = ScenarioConfig::default();
        let name = v.get("name");
        if !matches!(name, Json::Null) {
            sc.name = name
                .as_str()
                .ok_or_else(|| ConfigError("scenario: name must be a string".into()))?
                .to_string();
        }

        let frac = num_arr(v, "tier_fraction")?;
        let speed = num_arr(v, "tier_speed")?;
        let mu = num_arr(v, "tier_latency_mu")?;
        let sigma = num_arr(v, "tier_latency_sigma")?;
        if frac.is_some() || speed.is_some() {
            let frac = frac.ok_or_else(|| miss("tier_fraction"))?;
            let speed = speed.ok_or_else(|| miss("tier_speed"))?;
            same_len("tier_speed", speed.len(), frac.len())?;
            if let Some(m) = &mu {
                same_len("tier_latency_mu", m.len(), frac.len())?;
            }
            if let Some(s) = &sigma {
                same_len("tier_latency_sigma", s.len(), frac.len())?;
            }
            sc.tiers = frac
                .iter()
                .zip(&speed)
                .enumerate()
                .map(|(i, (&f, &sp))| SpeedTier {
                    fraction: f,
                    speed: sp,
                    // Slower tiers default to proportionally worse links.
                    latency_mu: match &mu {
                        Some(m) => m[i],
                        None => DEFAULT_LATENCY_MU - sp.max(f64::MIN_POSITIVE).ln(),
                    },
                    latency_sigma: match &sigma {
                        Some(s) => s[i],
                        None => DEFAULT_LATENCY_SIGMA,
                    },
                })
                .collect();
        } else if mu.is_some() || sigma.is_some() {
            return Err(miss("tier_fraction/tier_speed"));
        }

        let at = num_arr(v, "churn_at")?;
        let present = num_arr(v, "churn_present")?;
        match (at, present) {
            (Some(at), Some(present)) => {
                same_len("churn_present", present.len(), at.len())?;
                sc.churn = at
                    .iter()
                    .zip(&present)
                    .map(|(&a, &p)| ChurnPhase { at: a, present: p })
                    .collect();
            }
            (None, None) => {}
            _ => return Err(miss("churn_at/churn_present (both or neither)")),
        }

        let from = num_arr(v, "straggler_from")?;
        let until = num_arr(v, "straggler_until")?;
        let bfrac = num_arr(v, "straggler_fraction")?;
        let slow = num_arr(v, "straggler_slowdown")?;
        if from.is_some() || until.is_some() || bfrac.is_some() || slow.is_some() {
            let from = from.ok_or_else(|| miss("straggler_from"))?;
            let until = until.ok_or_else(|| miss("straggler_until"))?;
            let bfrac = bfrac.ok_or_else(|| miss("straggler_fraction"))?;
            let slow = slow.ok_or_else(|| miss("straggler_slowdown"))?;
            same_len("straggler_until", until.len(), from.len())?;
            same_len("straggler_fraction", bfrac.len(), from.len())?;
            same_len("straggler_slowdown", slow.len(), from.len())?;
            sc.bursts = (0..from.len())
                .map(|i| StragglerBurst {
                    from: from[i],
                    until: until[i],
                    fraction: bfrac[i],
                    slowdown: slow[i],
                })
                .collect();
        }

        sc.faults.drop_prob = num_or(v, "drop_prob", sc.faults.drop_prob)?;
        sc.faults.duplicate_prob = num_or(v, "duplicate_prob", sc.faults.duplicate_prob)?;

        sc.validate()?;
        Ok(sc)
    }

    /// Validate invariants; called by the parser and by config validation.
    pub fn validate(&self) -> Result<(), ConfigError> {
        let e = |m: String| Err(ConfigError(m));
        for (i, t) in self.tiers.iter().enumerate() {
            if !(t.fraction > 0.0 && t.fraction.is_finite()) {
                return e(format!("scenario tier {i}: fraction must be > 0, got {}", t.fraction));
            }
            if !(t.speed > 0.0 && t.speed.is_finite()) {
                return e(format!("scenario tier {i}: speed must be > 0, got {}", t.speed));
            }
            if !t.latency_mu.is_finite() || !(t.latency_sigma >= 0.0) {
                return e(format!("scenario tier {i}: bad latency params"));
            }
        }
        let mut prev_at = -1.0f64;
        for (i, c) in self.churn.iter().enumerate() {
            if !(0.0..=1.0).contains(&c.at) {
                return e(format!("scenario churn {i}: at={} outside [0, 1]", c.at));
            }
            if c.at < prev_at {
                return e(format!("scenario churn {i}: at={} not ascending", c.at));
            }
            prev_at = c.at;
            if !(c.present > 0.0 && c.present <= 1.0) {
                return e(format!(
                    "scenario churn {i}: present={} outside (0, 1]",
                    c.present
                ));
            }
        }
        for (i, b) in self.bursts.iter().enumerate() {
            if !(0.0..=1.0).contains(&b.from) || !(0.0..=1.0).contains(&b.until) || b.from >= b.until
            {
                return e(format!(
                    "scenario burst {i}: window [{}, {}) invalid",
                    b.from, b.until
                ));
            }
            if !(b.fraction > 0.0 && b.fraction <= 1.0) {
                return e(format!(
                    "scenario burst {i}: fraction={} outside (0, 1]",
                    b.fraction
                ));
            }
            if !(b.slowdown >= 1.0 && b.slowdown.is_finite()) {
                return e(format!(
                    "scenario burst {i}: slowdown={} must be >= 1",
                    b.slowdown
                ));
            }
        }
        let f = &self.faults;
        if !(0.0..1.0).contains(&f.drop_prob) || !(0.0..1.0).contains(&f.duplicate_prob) {
            return e(format!(
                "scenario faults: probabilities must be in [0, 1), got drop={} dup={}",
                f.drop_prob, f.duplicate_prob
            ));
        }
        if f.drop_prob + f.duplicate_prob > 0.9 {
            return e(format!(
                "scenario faults: drop+duplicate = {} leaves too few clean deliveries",
                f.drop_prob + f.duplicate_prob
            ));
        }
        Ok(())
    }

    /// Serialize for provenance headers (round-trips through `from_json`).
    pub fn to_json(&self) -> Json {
        let nums = |xs: Vec<f64>| Json::Arr(xs.into_iter().map(Json::Num).collect());
        let mut o = JsonObj::new();
        o.insert("name", Json::Str(self.name.clone()));
        if !self.tiers.is_empty() {
            o.insert("tier_fraction", nums(self.tiers.iter().map(|t| t.fraction).collect()));
            o.insert("tier_speed", nums(self.tiers.iter().map(|t| t.speed).collect()));
            o.insert("tier_latency_mu", nums(self.tiers.iter().map(|t| t.latency_mu).collect()));
            o.insert(
                "tier_latency_sigma",
                nums(self.tiers.iter().map(|t| t.latency_sigma).collect()),
            );
        }
        if !self.churn.is_empty() {
            o.insert("churn_at", nums(self.churn.iter().map(|c| c.at).collect()));
            o.insert("churn_present", nums(self.churn.iter().map(|c| c.present).collect()));
        }
        if !self.bursts.is_empty() {
            o.insert("straggler_from", nums(self.bursts.iter().map(|b| b.from).collect()));
            o.insert("straggler_until", nums(self.bursts.iter().map(|b| b.until).collect()));
            o.insert(
                "straggler_fraction",
                nums(self.bursts.iter().map(|b| b.fraction).collect()),
            );
            o.insert(
                "straggler_slowdown",
                nums(self.bursts.iter().map(|b| b.slowdown).collect()),
            );
        }
        if self.faults.drop_prob > 0.0 {
            o.insert("drop_prob", Json::Num(self.faults.drop_prob));
        }
        if self.faults.duplicate_prob > 0.0 {
            o.insert("duplicate_prob", Json::Num(self.faults.duplicate_prob));
        }
        Json::Obj(o)
    }
}

fn miss(key: &str) -> ConfigError {
    ConfigError(format!("scenario: missing {key}"))
}

fn same_len(key: &str, got: usize, want: usize) -> Result<(), ConfigError> {
    if got != want {
        return Err(ConfigError(format!(
            "scenario: {key} has {got} entries, expected {want}"
        )));
    }
    Ok(())
}

/// Read an optional numeric array field; a present-but-wrong-typed value
/// is an error, not an absence.
fn num_arr(v: &Json, key: &str) -> Result<Option<Vec<f64>>, ConfigError> {
    let node = v.get(key);
    if matches!(node, Json::Null) {
        return Ok(None);
    }
    let Some(items) = node.as_arr() else {
        return Err(ConfigError(format!(
            "scenario: {key} must be an array of numbers"
        )));
    };
    let mut out = Vec::with_capacity(items.len());
    for (i, item) in items.iter().enumerate() {
        out.push(item.as_f64().ok_or_else(|| {
            ConfigError(format!("scenario: {key}[{i}] must be a number"))
        })?);
    }
    Ok(Some(out))
}

/// Read an optional numeric scalar field with the same strictness.
fn num_or(v: &Json, key: &str, default: f64) -> Result<f64, ConfigError> {
    let node = v.get(key);
    if matches!(node, Json::Null) {
        return Ok(default);
    }
    node.as_f64()
        .ok_or_else(|| ConfigError(format!("scenario: {key} must be a number")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toml: &str) -> Result<ScenarioConfig, ConfigError> {
        let doc = crate::util::toml::parse(toml).unwrap();
        ScenarioConfig::from_json(doc.get("scenario"))
    }

    #[test]
    fn full_scenario_parses() {
        let sc = parse(
            r#"
            [scenario]
            name = "everything"
            tier_fraction = [0.6, 0.4]
            tier_speed = [1.0, 0.25]
            churn_at = [0.25, 0.6]
            churn_present = [0.5, 0.9]
            straggler_from = [0.4]
            straggler_until = [0.7]
            straggler_fraction = [0.25]
            straggler_slowdown = [8.0]
            drop_prob = 0.05
            duplicate_prob = 0.02
            "#,
        )
        .unwrap();
        assert_eq!(sc.name, "everything");
        assert_eq!(sc.tiers.len(), 2);
        // Default latency worsens for the slow tier.
        assert!(sc.tiers[1].latency_mu > sc.tiers[0].latency_mu);
        assert_eq!(sc.churn.len(), 2);
        assert_eq!(sc.bursts.len(), 1);
        assert_eq!(sc.faults.drop_prob, 0.05);
    }

    #[test]
    fn empty_scenario_is_default() {
        let sc = parse("[scenario]\nname = \"plain\"").unwrap();
        assert!(sc.tiers.is_empty() && sc.churn.is_empty() && sc.bursts.is_empty());
        assert_eq!(sc.faults, FaultModel::default());
    }

    #[test]
    fn mismatched_arrays_rejected() {
        assert!(parse("[scenario]\ntier_fraction = [0.5, 0.5]\ntier_speed = [1.0]").is_err());
        assert!(parse("[scenario]\nchurn_at = [0.5]").is_err());
        assert!(parse("[scenario]\nstraggler_from = [0.1]\nstraggler_until = [0.5]").is_err());
    }

    #[test]
    fn typos_and_wrong_types_rejected_not_ignored() {
        // A typo'd key must not silently degrade to the uniform baseline.
        assert!(parse("[scenario]\ntier_fractions = [0.5, 0.5]").is_err());
        // Present-but-scalar where an array is expected is an error.
        assert!(parse("[scenario]\ntier_fraction = 0.6\ntier_speed = 1.0").is_err());
        // Wrong-typed scalars and names error too.
        assert!(parse("[scenario]\ndrop_prob = \"lots\"").is_err());
        assert!(parse("[scenario]\nname = 7").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(parse("[scenario]\ntier_fraction = [0.0]\ntier_speed = [1.0]").is_err());
        assert!(parse("[scenario]\ntier_fraction = [1.0]\ntier_speed = [-1.0]").is_err());
        assert!(parse("[scenario]\nchurn_at = [0.5, 0.2]\nchurn_present = [0.5, 0.9]").is_err());
        assert!(parse("[scenario]\nchurn_at = [0.5]\nchurn_present = [0.0]").is_err());
        assert!(
            parse(
                "[scenario]\nstraggler_from = [0.5]\nstraggler_until = [0.4]\n\
                 straggler_fraction = [0.5]\nstraggler_slowdown = [2.0]"
            )
            .is_err()
        );
        assert!(parse("[scenario]\ndrop_prob = 0.8\nduplicate_prob = 0.5").is_err());
        assert!(parse("[scenario]\ndrop_prob = 1.0").is_err());
    }

    #[test]
    fn json_roundtrip() {
        let sc = parse(
            r#"
            [scenario]
            name = "rt"
            tier_fraction = [0.7, 0.3]
            tier_speed = [1.0, 0.5]
            churn_at = [0.5]
            churn_present = [0.6]
            drop_prob = 0.1
            "#,
        )
        .unwrap();
        let back = ScenarioConfig::from_json(&sc.to_json()).unwrap();
        assert_eq!(back, sc);
    }
}
