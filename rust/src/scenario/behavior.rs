//! [`ClientBehavior`]: the one trait every execution mode consults.
//!
//! The three coordinators advance time in incompatible units (sampled
//! epochs, emergent virtual seconds, threaded wallclock), so behavior is
//! queried on **run progress** `p ∈ [0, 1]` and answers four questions:
//!
//! * *who is here* — [`ClientBehavior::is_present`] /
//!   [`ClientBehavior::present_count`] (churn schedules),
//! * *how slow are they* — [`ClientBehavior::slowdown`] (speed tier ×
//!   straggler burst) and [`ClientBehavior::link_latency`] (per-tier
//!   log-normal links),
//! * *how stale do they read* — [`ClientBehavior::sample_staleness`]
//!   (the paper's uniform draw, biased high for slow devices),
//! * *does the update arrive* — [`ClientBehavior::delivery`]
//!   (drop / duplicate faults).
//!
//! [`UniformBehavior`] reproduces the pre-scenario semantics exactly
//! (uniform staleness, default latency model, everyone present, no
//! faults); [`ScenarioBehavior`] compiles a [`ScenarioConfig`] into
//! deterministic per-device assignments from a seed.

use std::sync::Arc;

use super::{ScenarioConfig, SpeedTier};
use crate::config::ExperimentConfig;
use crate::util::rng::Rng;

/// Fate of a completed update at the moment it reaches the server.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Delivery {
    /// Normal case: offered to the updater once.
    Deliver,
    /// Lost in transit: the device trained, the server never hears.
    Drop,
    /// At-least-once transport: offered twice (second copy one version
    /// staler whenever the first applied).
    Duplicate,
}

/// How a client population behaves over one run.
///
/// All methods take `&self` plus the caller's `Rng`, so one behavior
/// object is shared across the threaded server's scheduler, workers, and
/// updater without locks.
pub trait ClientBehavior: Send + Sync {
    /// Short label for logs.
    fn label(&self) -> String;

    /// Is `device` part of the federation at run progress `p`?
    fn is_present(&self, device: usize, progress: f64) -> bool;

    /// Number of participating devices at progress `p` (the metric rows'
    /// `clients` column). Always in `[1, n]`.
    fn present_count(&self, progress: f64) -> usize;

    /// Multiplicative compute slowdown for `device` at progress `p`
    /// (speed tier × any active straggler burst; 1.0 = nominal).
    fn slowdown(&self, device: usize, progress: f64) -> f64;

    /// One network-hop latency draw for `device`, in virtual seconds.
    fn link_latency(&self, device: usize, rng: &mut Rng) -> f64;

    /// Staleness draw for the paper's sampled protocol, in `[1, max]`.
    fn sample_staleness(&self, device: usize, progress: f64, max: u64, rng: &mut Rng) -> u64;

    /// What happens to a completed update from `device` at delivery time.
    fn delivery(&self, device: usize, progress: f64, rng: &mut Rng) -> Delivery;
}

/// Build the behavior an experiment config asks for: a compiled
/// [`ScenarioBehavior`] when `cfg.scenario` is set, else the baseline
/// [`UniformBehavior`].
pub fn behavior_for(cfg: &ExperimentConfig, devices: usize, seed: u64) -> Arc<dyn ClientBehavior> {
    match &cfg.scenario {
        Some(sc) => Arc::new(ScenarioBehavior::new(sc, devices, seed)),
        None => Arc::new(UniformBehavior::new(devices)),
    }
}

/// Pick a device that is present at progress `p`: rejection-sample a few
/// uniform draws (cheap, unbiased when most of the fleet is present), then
/// fall back to a uniform pick over the present set.
pub fn pick_present(
    n: usize,
    behavior: &dyn ClientBehavior,
    progress: f64,
    rng: &mut Rng,
) -> usize {
    for _ in 0..8 {
        let d = rng.index(n);
        if behavior.is_present(d, progress) {
            return d;
        }
    }
    let present: Vec<usize> = (0..n).filter(|&d| behavior.is_present(d, progress)).collect();
    if present.is_empty() {
        // Unreachable for validated configs (present fraction > 0), but
        // never wedge a simulation over it.
        return rng.index(n);
    }
    present[rng.index(present.len())]
}

/// The pre-scenario population: homogeneous, always present, faithful
/// links, uniform staleness.
#[derive(Debug, Clone)]
pub struct UniformBehavior {
    n: usize,
    tier: SpeedTier,
}

impl UniformBehavior {
    /// Baseline population over `devices` clients.
    pub fn new(devices: usize) -> UniformBehavior {
        UniformBehavior { n: devices.max(1), tier: SpeedTier::nominal() }
    }
}

impl ClientBehavior for UniformBehavior {
    fn label(&self) -> String {
        "uniform".into()
    }

    fn is_present(&self, _device: usize, _progress: f64) -> bool {
        true
    }

    fn present_count(&self, _progress: f64) -> usize {
        self.n
    }

    fn slowdown(&self, _device: usize, _progress: f64) -> f64 {
        1.0
    }

    fn link_latency(&self, _device: usize, rng: &mut Rng) -> f64 {
        rng.lognormal(self.tier.latency_mu, self.tier.latency_sigma)
    }

    fn sample_staleness(&self, _device: usize, _progress: f64, max: u64, rng: &mut Rng) -> u64 {
        rng.range_inclusive(1, max.max(1))
    }

    fn delivery(&self, _device: usize, _progress: f64, _rng: &mut Rng) -> Delivery {
        Delivery::Deliver
    }
}

/// Dense bitset: burst membership for a million-client fleet is one bit
/// per device (125 KB at n = 1M) instead of a `Vec<bool>` byte per
/// device.
#[derive(Debug, Clone)]
struct Bitset {
    words: Vec<u64>,
}

impl Bitset {
    fn new(n: usize) -> Bitset {
        Bitset { words: vec![0u64; n.div_ceil(64)] }
    }

    fn set(&mut self, i: usize) {
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    fn get(&self, i: usize) -> bool {
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }
}

/// A [`ScenarioConfig`] compiled for a concrete fleet: per-device tier
/// assignment, churn ranks, and burst membership are all drawn once from
/// the seed, so every mode sees the identical population.
///
/// State is structure-of-arrays, sized for fleets of 10⁶+ devices: tier
/// assignment is one `u16` per device, churn rank one `u32`, burst
/// membership one *bit* — ~7 bytes/device total, versus the ~50 the
/// original per-client layout needed.  Every RNG draw (compile-time
/// shuffles and `choose_k`, query-time latency/staleness/delivery draws)
/// and every floating-point operation happens in the identical order as
/// [`super::reference::ReferenceScenarioBehavior`], the retired
/// per-client implementation kept as the property-test oracle
/// (`prop_soa_behavior_matches_reference`), so decisions are pinned
/// draw-for-draw and bit-for-bit.
pub struct ScenarioBehavior {
    name: String,
    n: usize,
    /// Per-tier `1.0 / speed` (the value `slowdown` starts from; dividing
    /// once at compile time is bit-identical to dividing per query).
    tier_inv_speed: Vec<f64>,
    /// Per-tier log-normal link-latency μ.
    tier_latency_mu: Vec<f64>,
    /// Per-tier log-normal link-latency σ.
    tier_latency_sigma: Vec<f64>,
    /// Tier index per device.
    tier_of: Vec<u16>,
    /// Devices with `churn_rank < present_count(p)` are present at `p`.
    churn_rank: Vec<u32>,
    churn: Vec<super::ChurnPhase>,
    /// Burst windows, in config order (the order `slowdown` multiplies).
    bursts: Vec<super::StragglerBurst>,
    /// One membership bitset per burst, parallel to `bursts`.
    burst_members: Vec<Bitset>,
    faults: super::FaultModel,
}

impl ScenarioBehavior {
    /// Compile `sc` for a fleet of `devices`, drawing every per-device
    /// assignment deterministically from `seed`.
    ///
    /// The draw protocol (tier-deal shuffle, churn-rank shuffle, one
    /// `choose_k` per burst) is pinned against the reference model —
    /// shuffle and `choose_k` consume RNG draws as a function of length
    /// only, so the compact element types cannot shift the stream.
    pub fn new(sc: &ScenarioConfig, devices: usize, seed: u64) -> ScenarioBehavior {
        assert!(devices > 0, "scenario behavior needs a non-empty fleet");
        assert!(devices <= u32::MAX as usize, "fleet too large for u32 churn ranks");
        let n = devices;
        let mut rng = Rng::seed_from(seed ^ 0x5CE4_4210);

        // Normalize tiers (empty = single nominal tier) and deal devices
        // into them in a seeded random order.
        let tiers: Vec<SpeedTier> = if sc.tiers.is_empty() {
            vec![SpeedTier::nominal()]
        } else {
            let total: f64 = sc.tiers.iter().map(|t| t.fraction).sum();
            sc.tiers
                .iter()
                .map(|t| SpeedTier { fraction: t.fraction / total, ..t.clone() })
                .collect()
        };
        assert!(tiers.len() <= u16::MAX as usize, "too many tiers for u16 indices");
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut tier_of = vec![0u16; n];
        let mut acc = 0.0f64;
        let mut start = 0usize;
        for (ti, t) in tiers.iter().enumerate() {
            acc += t.fraction;
            let end = if ti + 1 == tiers.len() {
                n
            } else {
                ((acc * n as f64).round() as usize).min(n)
            };
            for &d in &order[start..end.max(start)] {
                tier_of[d] = ti as u16;
            }
            start = end.max(start);
        }

        // Churn ranks: an independent shuffle decides who leaves first.
        let mut churn_order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut churn_order);
        let mut churn_rank = vec![0u32; n];
        for (rank, &d) in churn_order.iter().enumerate() {
            churn_rank[d] = rank as u32;
        }

        // Burst membership: an independent draw per burst.
        let mut bursts = Vec::with_capacity(sc.bursts.len());
        let mut burst_members = Vec::with_capacity(sc.bursts.len());
        for b in &sc.bursts {
            let k = ((b.fraction * n as f64).ceil() as usize).clamp(1, n);
            let mut member = Bitset::new(n);
            for d in rng.choose_k(n, k) {
                member.set(d);
            }
            bursts.push(*b);
            burst_members.push(member);
        }

        ScenarioBehavior {
            name: sc.name.clone(),
            n,
            tier_inv_speed: tiers.iter().map(|t| 1.0 / t.speed).collect(),
            tier_latency_mu: tiers.iter().map(|t| t.latency_mu).collect(),
            tier_latency_sigma: tiers.iter().map(|t| t.latency_sigma).collect(),
            tier_of,
            churn_rank,
            churn: sc.churn.clone(),
            bursts,
            burst_members,
            faults: sc.faults,
        }
    }

    /// Present fraction of the fleet at progress `p` (last phase at or
    /// before `p` wins; 1.0 before the first phase).
    fn present_level(&self, progress: f64) -> f64 {
        let mut level = 1.0;
        for c in &self.churn {
            if c.at <= progress {
                level = c.present;
            } else {
                break;
            }
        }
        level
    }

    fn tier_index(&self, device: usize) -> usize {
        self.tier_of[device.min(self.n - 1)] as usize
    }
}

impl ClientBehavior for ScenarioBehavior {
    fn label(&self) -> String {
        self.name.clone()
    }

    fn is_present(&self, device: usize, progress: f64) -> bool {
        (self.churn_rank[device.min(self.n - 1)] as usize) < self.present_count(progress)
    }

    fn present_count(&self, progress: f64) -> usize {
        ((self.present_level(progress) * self.n as f64).ceil() as usize).clamp(1, self.n)
    }

    fn slowdown(&self, device: usize, progress: f64) -> f64 {
        let mut s = self.tier_inv_speed[self.tier_index(device)];
        for (b, member) in self.bursts.iter().zip(&self.burst_members) {
            if member.get(device.min(self.n - 1)) && progress >= b.from && progress < b.until {
                s *= b.slowdown;
            }
        }
        s
    }

    fn link_latency(&self, device: usize, rng: &mut Rng) -> f64 {
        let ti = self.tier_index(device);
        rng.lognormal(self.tier_latency_mu[ti], self.tier_latency_sigma[ti])
    }

    fn sample_staleness(&self, device: usize, progress: f64, max: u64, rng: &mut Rng) -> u64 {
        // Uniform draw reshaped by the device's slowdown: for a nominal
        // device (slowdown 1) `1 + floor(u·max)` is exactly the paper's
        // uniform [1, max]; slower devices bias u^(1/slowdown) toward 1,
        // i.e. toward reading older models — the sampled-protocol
        // counterpart of their longer in-flight windows.
        let max = max.max(1);
        let sl = self.slowdown(device, progress).max(1e-6);
        let u = rng.f64().powf(1.0 / sl);
        (1 + (u * max as f64).floor() as u64).min(max)
    }

    fn delivery(&self, _device: usize, _progress: f64, rng: &mut Rng) -> Delivery {
        let f = &self.faults;
        if f.drop_prob <= 0.0 && f.duplicate_prob <= 0.0 {
            return Delivery::Deliver;
        }
        let u = rng.f64();
        if u < f.drop_prob {
            Delivery::Drop
        } else if u < f.drop_prob + f.duplicate_prob {
            Delivery::Duplicate
        } else {
            Delivery::Deliver
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{ChurnPhase, FaultModel, StragglerBurst};
    use super::*;

    fn scenario() -> ScenarioConfig {
        ScenarioConfig {
            name: "test".into(),
            tiers: vec![
                SpeedTier { fraction: 0.5, speed: 1.0, latency_mu: -3.0, latency_sigma: 0.8 },
                SpeedTier { fraction: 0.5, speed: 0.25, latency_mu: -1.5, latency_sigma: 0.8 },
            ],
            churn: vec![
                ChurnPhase { at: 0.25, present: 0.5 },
                ChurnPhase { at: 0.75, present: 0.9 },
            ],
            bursts: vec![StragglerBurst { from: 0.4, until: 0.6, fraction: 0.25, slowdown: 8.0 }],
            faults: FaultModel { drop_prob: 0.2, duplicate_prob: 0.1 },
        }
    }

    #[test]
    fn uniform_matches_paper_protocol() {
        let b = UniformBehavior::new(10);
        let mut rng = Rng::seed_from(1);
        assert_eq!(b.present_count(0.5), 10);
        assert!(b.is_present(3, 0.9));
        assert_eq!(b.slowdown(0, 0.5), 1.0);
        let mut seen = [false; 17];
        for _ in 0..2000 {
            let s = b.sample_staleness(0, 0.5, 16, &mut rng);
            assert!((1..=16).contains(&s));
            seen[s as usize] = true;
        }
        assert!(seen[1..=16].iter().all(|&x| x), "uniform draw misses values");
        assert_eq!(b.delivery(0, 0.5, &mut rng), Delivery::Deliver);
    }

    #[test]
    fn tier_assignment_covers_fleet_and_is_deterministic() {
        let sc = scenario();
        let a = ScenarioBehavior::new(&sc, 40, 7);
        let b = ScenarioBehavior::new(&sc, 40, 7);
        assert_eq!(a.tier_of, b.tier_of);
        let slow = a.tier_of.iter().filter(|&&t| t == 1).count();
        assert!((15..=25).contains(&slow), "slow tier size {slow}");
        // Slow tier really is slower and has worse links (in expectation).
        let fast_d = a.tier_of.iter().position(|&t| t == 0).unwrap();
        let slow_d = a.tier_of.iter().position(|&t| t == 1).unwrap();
        assert!(a.slowdown(slow_d, 0.0) > a.slowdown(fast_d, 0.0));
    }

    #[test]
    fn churn_schedule_shrinks_and_recovers() {
        let b = ScenarioBehavior::new(&scenario(), 40, 3);
        assert_eq!(b.present_count(0.0), 40);
        assert_eq!(b.present_count(0.3), 20);
        assert_eq!(b.present_count(0.8), 36);
        for p in [0.0, 0.3, 0.8] {
            let present = (0..40).filter(|&d| b.is_present(d, p)).count();
            assert_eq!(present, b.present_count(p), "p={p}");
        }
        // The present set is nested: whoever survives the deep cut is
        // present at every higher level.
        for d in 0..40 {
            if b.is_present(d, 0.3) {
                assert!(b.is_present(d, 0.8) && b.is_present(d, 0.0));
            }
        }
    }

    #[test]
    fn straggler_burst_is_windowed() {
        let b = ScenarioBehavior::new(&scenario(), 40, 3);
        let member = (0..40)
            .find(|&d| b.slowdown(d, 0.5) > b.slowdown(d, 0.1) * 4.0)
            .expect("some burst member");
        assert_eq!(b.slowdown(member, 0.1), b.slowdown(member, 0.7));
        assert!((b.slowdown(member, 0.5) / b.slowdown(member, 0.1) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn slow_devices_draw_staler_models() {
        let b = ScenarioBehavior::new(&scenario(), 40, 3);
        let fast_d = b.tier_of.iter().position(|&t| t == 0).unwrap();
        let slow_d = b.tier_of.iter().position(|&t| t == 1).unwrap();
        let mut rng = Rng::seed_from(11);
        let mean = |d: usize, rng: &mut Rng| {
            (0..4000).map(|_| b.sample_staleness(d, 0.1, 16, rng)).sum::<u64>() as f64 / 4000.0
        };
        let m_fast = mean(fast_d, &mut rng);
        let m_slow = mean(slow_d, &mut rng);
        assert!(
            m_slow > m_fast + 2.0,
            "slow mean {m_slow} should exceed fast mean {m_fast}"
        );
    }

    #[test]
    fn delivery_fault_rates_are_roughly_configured() {
        let b = ScenarioBehavior::new(&scenario(), 40, 3);
        let mut rng = Rng::seed_from(5);
        let (mut drops, mut dups) = (0, 0);
        let n = 10_000;
        for _ in 0..n {
            match b.delivery(0, 0.5, &mut rng) {
                Delivery::Drop => drops += 1,
                Delivery::Duplicate => dups += 1,
                Delivery::Deliver => {}
            }
        }
        let (dr, du) = (drops as f64 / n as f64, dups as f64 / n as f64);
        assert!((dr - 0.2).abs() < 0.02, "drop rate {dr}");
        assert!((du - 0.1).abs() < 0.02, "dup rate {du}");
    }

    #[test]
    fn pick_present_respects_churn() {
        let b = ScenarioBehavior::new(&scenario(), 40, 3);
        let mut rng = Rng::seed_from(2);
        for _ in 0..200 {
            let d = pick_present(40, &b, 0.3, &mut rng);
            assert!(b.is_present(d, 0.3), "picked absent device {d}");
        }
    }
}
