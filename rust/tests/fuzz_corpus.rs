//! Tier-1 replay of the checked-in fuzz regression corpus, plus a
//! bounded smoke pass over every fuzz target.
//!
//! The corpus (`rust/tests/fixtures/fuzz_corpus/<target>/`) is the
//! permanent record of inputs that once broke a parser or an execution
//! invariant: every entry must stay green on every build.  The smoke
//! pass runs each target for a small, fixed-seed iteration budget so a
//! freshly introduced panic path fails here — in `cargo test` — before
//! CI's deeper `fuzz_driver` matrix ever runs.

use fedasync::fuzzing::{replay_corpus, run_target, targets};

#[test]
fn corpus_replays_clean() {
    let mut total = 0;
    for t in targets::all() {
        match replay_corpus(t) {
            Ok(n) => {
                assert!(n > 0, "target {} has no corpus entries — directory missing?", t.name);
                total += n;
            }
            Err(msg) => panic!("target {}: {msg}", t.name),
        }
    }
    assert!(total >= 20, "corpus suspiciously small: {total} entries");
}

#[test]
fn fuzz_smoke_parsers_hold_under_seeded_bombardment() {
    for t in targets::all() {
        if t.name == "differential" {
            continue; // covered by its own (expensive) smoke below
        }
        let iters = match t.name {
            "event_queue" => 300,
            // Some length classes straddle 2·SHARD_MIN_LEN (~64k elements
            // per pass); a reduced budget keeps tier-1 debug builds fast.
            "kernel_equivalence" => 100,
            _ => 200,
        };
        let summary = run_target(t, 1, iters, 256);
        if let Some(f) = &summary.failure {
            panic!(
                "target {} failed at iter {} (seed 1): {}\n  shrunk input: {:?}",
                t.name, f.iter, f.message, f.shrunk
            );
        }
    }
}

#[test]
fn fuzz_smoke_differential_drivers_conform() {
    let t = targets::find("differential").expect("differential target registered");
    let summary = run_target(t, 1, 2, 64);
    if let Some(f) = &summary.failure {
        panic!(
            "differential execution diverged at iter {} (seed 1): {}\n  config bytes: {:?}",
            f.iter, f.message, f.shrunk
        );
    }
}
