//! Threaded-server topology tests on a native mock compute service.
//!
//! `run_server_core` exposes the real scheduler ∥ workers ∥ updater
//! machinery behind a `ComputeJob` channel, so these tests exercise the
//! snapshot-cell handoff, the shared updater core, the eval grid, and the
//! shutdown drain **without PJRT artifacts** — the mock service answers
//! `Train`/`Eval` with closed-form math (every update moves the model 10%
//! of the way toward the all-ones vector).
//!
//! The decision-equivalence guarantee (threaded drop/mix == a hand-rolled
//! `Updater::apply` loop over the same update sequence) is pinned at the
//! `UpdaterCore` level in `coordinator::core`'s unit tests; everything the
//! threaded server applies flows through that same `offer` path.

use std::sync::mpsc;
use std::time::Duration;

use fedasync::config::{ExecMode, ExperimentConfig, LocalUpdate};
use fedasync::coordinator::server::{run_server_core, ComputeJob};
use fedasync::federated::data::Dataset;
use fedasync::federated::metrics::MetricsLog;
use fedasync::runtime::EvalMetrics;
use fedasync::scenario;

/// Local iterations the mock pretends to run (gradient accounting).
const H: usize = 5;

/// Closed-form stand-in for the PJRT service: one "local epoch" moves
/// every parameter 10% toward 1.0; eval reports mean squared distance
/// from 1.0 as loss.
fn mock_service(jobs: mpsc::Receiver<ComputeJob>) {
    let mut scratch = fedasync::coordinator::TaskScratch::new();
    while let Ok(job) = jobs.recv() {
        match job {
            ComputeJob::Train { params, reply, .. } => {
                let mut x_new = scratch.acquire(params.len());
                x_new.extend(params.iter().map(|&v| v + 0.1 * (1.0 - v)));
                let loss =
                    params.iter().map(|&v| (1.0 - v).abs()).sum::<f32>() / params.len() as f32;
                let _ = reply.send(Ok((x_new, loss)));
            }
            ComputeJob::Recycle(buf) => scratch.release(buf),
            ComputeJob::Eval { params, reply } => {
                let loss = params
                    .iter()
                    .map(|&v| ((1.0 - v) as f64).powi(2))
                    .sum::<f64>()
                    / params.len() as f64;
                let _ = reply.send(Ok(EvalMetrics {
                    loss,
                    accuracy: (1.0 - loss).max(0.0),
                    samples: params.len(),
                }));
            }
        }
    }
}

fn threads_cfg(epochs: usize, eval_every: usize, workers: usize, inflight: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.mode = ExecMode::Threads;
    cfg.local_update = LocalUpdate::Sgd;
    cfg.epochs = epochs;
    cfg.eval_every = eval_every;
    cfg.worker_threads = workers;
    cfg.max_inflight = inflight;
    cfg.alpha = 0.5;
    cfg.alpha_decay = 1.0;
    cfg.alpha_decay_at = usize::MAX;
    cfg.federation.devices = 8;
    cfg
}

fn dummy_test_set() -> Dataset {
    Dataset { features: vec![0.0; 4], labels: vec![0], input_size: 4, num_classes: 10 }
}

/// Run the core against the mock service on a watchdog: a hang in the
/// teardown drain fails the test instead of wedging the suite.
fn run_with_watchdog(cfg: ExperimentConfig, seed: u64, timeout: Duration) -> MetricsLog {
    let (job_tx, job_rx) = mpsc::channel::<ComputeJob>();
    let svc = std::thread::spawn(move || mock_service(job_rx));
    let (done_tx, done_rx) = mpsc::channel();
    std::thread::spawn(move || {
        let test = dummy_test_set();
        let behavior = scenario::behavior_for(&cfg, cfg.federation.devices, seed);
        let result = run_server_core(&cfg, seed, &test, vec![0.0f32; 32], H, job_tx, behavior);
        let _ = done_tx.send(result);
    });
    let result = done_rx
        .recv_timeout(timeout)
        .expect("threaded server deadlocked during run/teardown");
    svc.join().expect("mock service join");
    result.expect("threaded run failed")
}

#[test]
fn teardown_does_not_deadlock_at_minimum_concurrency() {
    // Regression for the shutdown drain: with max_inflight = 1 and a
    // single worker, every channel is at capacity-1 and the
    // scheduler/worker/updater unwind order matters.
    let log = run_with_watchdog(threads_cfg(12, 4, 1, 1), 7, Duration::from_secs(60));
    let last = log.rows.last().expect("rows");
    assert!(last.epoch >= 12, "stopped early at {}", last.epoch);
}

#[test]
fn rows_land_exactly_on_the_eval_grid() {
    // The seed's threaded server kept its own `next_eval` cursor and
    // drifted off the 0, k, 2k, … grid; routing through EvalRecorder
    // makes the grid exact even with concurrent, stale updates.
    let log = run_with_watchdog(threads_cfg(40, 10, 3, 4), 3, Duration::from_secs(120));
    let epochs: Vec<usize> = log.rows.iter().map(|r| r.epoch).collect();
    assert_eq!(epochs, vec![0, 10, 20, 30, 40]);
    let first = &log.rows[0];
    let last = log.rows.last().unwrap();
    // The mock contracts toward 1.0, so held-out loss must fall…
    assert!(
        last.test_loss < first.test_loss * 0.7,
        "no training progress: {} -> {}",
        first.test_loss,
        last.test_loss
    );
    // …and emergent staleness is at least 1 (freshest-possible update).
    assert!(last.staleness >= 1.0, "staleness {}", last.staleness);
    // sim_time is virtual seconds now — a short run is far below the
    // wallclock-seconds magnitude the old bug reported, but nonzero.
    assert!(last.sim_time.is_finite() && last.sim_time > 0.0);
    // Server accounting: 2 comms per offered task, H gradients per apply.
    assert_eq!(last.gradients, 40 * H as u64);
    assert!(last.comms >= 80, "comms {}", last.comms);
}

#[test]
fn scenario_faults_and_churn_still_reach_the_epoch_target() {
    // A lossy, churning population must not wedge the threaded topology:
    // dropped deliveries never advance the version (no gradients), the
    // scheduler only triggers present devices, and the run still reaches
    // its epoch target because the scheduler keeps feeding tasks.
    let mut cfg = threads_cfg(24, 8, 3, 4);
    let mut sc = scenario::presets::named("lossy_uplink").expect("preset");
    sc.churn = vec![fedasync::scenario::ChurnPhase { at: 0.5, present: 0.5 }];
    cfg.scenario = Some(sc);
    cfg.validate().expect("scenario config valid");
    let log = run_with_watchdog(cfg, 13, Duration::from_secs(120));
    let last = log.rows.last().unwrap();
    assert!(last.epoch >= 24, "stopped early at {}", last.epoch);
    assert_eq!(last.gradients, 24 * H as u64, "only applied updates count gradients");
    // Churn is visible in the clients column: full fleet at t=0, half
    // after the midpoint phase.
    assert_eq!(log.rows[0].clients, 8);
    assert_eq!(last.clients, 4);
    // The histogram saw every offered update.
    assert!(log.staleness_hist.total() >= 24);
    assert!(!log.staleness_hist.support().is_empty());
}

#[test]
fn drop_policy_drops_but_still_terminates() {
    // With drop_above = 1 only freshest updates apply; stale ones are
    // dropped (counted as comms, not gradients) and the server must still
    // reach its epoch target.
    let mut cfg = threads_cfg(20, 5, 3, 4);
    cfg.staleness.max = 16;
    cfg.staleness.drop_above = Some(1);
    let log = run_with_watchdog(cfg, 11, Duration::from_secs(120));
    let last = log.rows.last().unwrap();
    assert!(last.epoch >= 20);
    assert_eq!(last.gradients, 20 * H as u64, "only applied updates count gradients");
    // Dropped tasks still cost communication, so comms exceed 2/epoch
    // whenever any drop happened (with 3 workers racing, some must).
    assert!(last.comms >= 40, "comms {}", last.comms);
}
