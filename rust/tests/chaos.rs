//! Chaos-plane conformance: the serving plane under injected faults.
//!
//! Everything here runs the same loopback harness as `serving.rs` — a
//! real `TcpListener`, swarm-client threads speaking the wire protocol,
//! the closed-form quadratic compute plane — but with the fault
//! injector armed on one or both sides of the socket, and with the
//! crash/checkpoint/resume machinery in the loop:
//!
//! * kill the server at a chosen model version and resume it from its
//!   checkpoint on a fresh port — training completes, and summing the
//!   clients' `applied` acks re-derives the final model version exactly
//!   (nothing lost, nothing double-applied, across a process boundary);
//! * a drop/delay-only fault plan on both sides of every socket still
//!   lands inside the cross-mode conformance band on the straggler and
//!   churn presets;
//! * retried pushes under one sequence number are answered from the
//!   dedup table — byte-identical acks, model version untouched;
//! * the client's attempt cap terminates retry loops against a server
//!   that sheds forever;
//! * a plan with every fault type armed (resets, truncations, duplicated
//!   frames, bit flips) cannot wedge the run or over-count applies.

use std::net::TcpListener;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::Duration;

use fedasync::analysis::quadratic::{dummy_dataset, dummy_fleet, QuadraticProblem};
use fedasync::chaos::{ChaosConfig, FaultPlan};
use fedasync::config::{ExecMode, ExperimentConfig, LocalUpdate, ServingConfig, StalenessFn};
use fedasync::coordinator::server::{run_server_core, serve_native, ComputeJob};
use fedasync::coordinator::Trainer;
use fedasync::federated::metrics::MetricsLog;
use fedasync::runtime::RuntimeError;
use fedasync::scenario;
use fedasync::serving::wire::write_frame;
use fedasync::serving::{
    run_quad_client, run_served_core, AddrCell, ClientLoop, ClientOpts, ClientReport, Frame,
    FrameReader, PushOutcome, ServingStats, SwarmClient,
};

const CONF_DEVICES: usize = 16;
const CONF_EPOCHS: usize = 120;
const CONF_SEED: u64 = 1;
const CLIENTS: usize = 3;

fn conformance_quad() -> QuadraticProblem {
    // Same problem as serving.rs / integration_training.rs, so the
    // shared loss band means the same thing here.
    QuadraticProblem::new(CONF_DEVICES, 6, 0.5, 2.0, 2.0, 0.05, 5, 3)
}

fn conformance_shrink(cfg: &mut ExperimentConfig) {
    cfg.mode = ExecMode::Threads;
    cfg.epochs = CONF_EPOCHS;
    cfg.eval_every = CONF_EPOCHS / 4;
    cfg.repeats = 1;
    cfg.seed = CONF_SEED;
    cfg.gamma = 0.05;
    cfg.alpha = 0.6;
    cfg.alpha_decay = 1.0;
    cfg.alpha_decay_at = usize::MAX;
    cfg.local_update = LocalUpdate::Sgd;
    cfg.staleness.func = StalenessFn::Poly { a: 0.5 };
    cfg.federation.devices = CONF_DEVICES;
    cfg.worker_threads = CLIENTS;
    cfg.max_inflight = 4;
    cfg.serving = Some(ServingConfig::default());
    cfg.validate().expect("conformance serving config");
}

fn preset_cfg(name: &str) -> ExperimentConfig {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs").join(name);
    let mut cfg =
        ExperimentConfig::from_toml_file(&path).unwrap_or_else(|e| panic!("{path:?}: {e}"));
    assert!(cfg.scenario.is_some(), "{path:?} must carry a [scenario] table");
    conformance_shrink(&mut cfg);
    cfg
}

/// Plain config (no scenario): uniform population, every delivery lands.
fn plain_cfg(epochs: usize, eval_every: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    conformance_shrink(&mut cfg);
    cfg.epochs = epochs;
    cfg.eval_every = eval_every;
    cfg.validate().expect("plain serving config");
    cfg
}

/// The in-process threaded baseline over the native quadratic service.
fn run_threaded_baseline(cfg: &ExperimentConfig) -> MetricsLog {
    let p = conformance_quad();
    let init = p.init_params(CONF_SEED as usize).expect("init");
    let h = p.local_iters();
    let (job_tx, job_rx) = mpsc::channel::<ComputeJob>();
    let svc = std::thread::spawn(move || serve_native(conformance_quad(), CONF_DEVICES, job_rx));
    let behavior = scenario::behavior_for(cfg, CONF_DEVICES, CONF_SEED);
    let test = dummy_dataset();
    let log = run_server_core(cfg, CONF_SEED, &test, init, h, job_tx, behavior)
        .unwrap_or_else(|e| panic!("threaded baseline: {e}"));
    svc.join().expect("native service join");
    log
}

/// Spawn the served engine behind `listener` (with its own native
/// compute thread) and hand back the completion channel — the caller
/// decides the watchdog budget and whether an `Err` is expected (the
/// crash/resume test *wants* one).
fn spawn_served(
    cfg: &ExperimentConfig,
    listener: TcpListener,
    stats: Arc<ServingStats>,
) -> (mpsc::Receiver<Result<MetricsLog, RuntimeError>>, std::thread::JoinHandle<()>) {
    let p = conformance_quad();
    let init = p.init_params(CONF_SEED as usize).expect("init");
    let h = p.local_iters();
    let (job_tx, job_rx) = mpsc::channel::<ComputeJob>();
    let svc = std::thread::spawn(move || serve_native(conformance_quad(), CONF_DEVICES, job_rx));
    let behavior = scenario::behavior_for(cfg, CONF_DEVICES, CONF_SEED);
    let (done_tx, done_rx) = mpsc::channel();
    let cfg = cfg.clone();
    std::thread::spawn(move || {
        let test = dummy_dataset();
        let result =
            run_served_core(&cfg, CONF_SEED, &test, init, h, job_tx, behavior, listener, stats);
        let _ = done_tx.send(result);
    });
    (done_rx, svc)
}

/// A full served run with tracked (exactly-once) clients and an optional
/// client-side fault plan; the server-side plan rides in `cfg.chaos`.
fn run_chaos_loopback(
    cfg: &ExperimentConfig,
    client_plan: Option<Arc<FaultPlan>>,
    clients: usize,
    deadline: Duration,
    watchdog: Duration,
) -> (MetricsLog, Vec<ClientReport>, Arc<ServingStats>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr");
    let stats = Arc::new(ServingStats::default());
    let (done_rx, svc) = spawn_served(cfg, listener, Arc::clone(&stats));

    let behavior = scenario::behavior_for(cfg, CONF_DEVICES, CONF_SEED);
    let epochs = cfg.epochs as u64;
    let (gamma, rho) = (cfg.gamma, cfg.rho);
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let behavior = Arc::clone(&behavior);
            let plan = client_plan.clone();
            std::thread::spawn(move || {
                let trainer = conformance_quad();
                let mut fleet = dummy_fleet(CONF_DEVICES, 7);
                let data = dummy_dataset();
                let loop_cfg = ClientLoop {
                    behavior: behavior.as_ref(),
                    devices: CONF_DEVICES,
                    epochs,
                    gamma,
                    rho,
                    seed: CONF_SEED + 100 * (c as u64 + 1),
                    deadline,
                    client_id: c as u64 + 1,
                    max_push_attempts: 0,
                    chaos: plan,
                };
                run_quad_client(addr, &trainer, &mut fleet, &data, &loop_cfg)
                    .unwrap_or_else(|e| panic!("client {c}: {e}"))
            })
        })
        .collect();

    let result = done_rx.recv_timeout(watchdog).expect("served engine deadlocked under chaos");
    let log = result.expect("served run failed");
    let reports: Vec<ClientReport> =
        handles.into_iter().map(|h| h.join().expect("client join")).collect();
    svc.join().expect("native service join");
    (log, reports, stats)
}

/// Conformance bands shared with serving.rs: both runs learn, finals
/// share a 100× band, staleness supports overlap.
fn assert_conformant(preset: &str, served: &MetricsLog, threaded: &MetricsLog) {
    let mut finals = Vec::new();
    for (mode, log) in [("chaos-served", served), ("threaded", threaded)] {
        let first = log.rows.first().expect("rows").test_loss;
        let last = log.rows.last().expect("rows").test_loss;
        assert!(
            last.is_finite() && last < first * 0.5,
            "{preset} {mode}: no learning ({first} -> {last})"
        );
        assert!(log.staleness_hist.total() > 0, "{preset} {mode}: empty staleness histogram");
        finals.push(last);
    }
    let lo = finals.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = finals.iter().cloned().fold(0.0f64, f64::max);
    assert!(
        hi <= lo.max(1e-3) * 100.0,
        "{preset}: faulted served vs threaded final losses diverged: {finals:?}"
    );
    let a: std::collections::BTreeSet<u64> = served.staleness_hist.support().into_iter().collect();
    let b: std::collections::BTreeSet<u64> =
        threaded.staleness_hist.support().into_iter().collect();
    assert!(
        a.intersection(&b).next().is_some(),
        "{preset}: staleness supports are disjoint: {a:?} vs {b:?}"
    );
}

// ---------------------------------------------------------------- tentpole

#[test]
fn crash_and_resume_preserves_exactly_once() {
    // Kill the server (injected crash, ack dropped on the floor) once the
    // model reaches version 25, restart it on a *different* port from its
    // checkpoint, and let the same swarm finish the run through an
    // AddrCell redial.  With checkpoint_every = 1 every ack the clients
    // ever saw is durable, so the conservation law must hold across the
    // crash: Σ applied acks == final model version.  The ack in flight at
    // the crash is replayed from the restored dedup table — the update is
    // *not* applied twice.
    const EPOCHS: usize = 60;
    const CRASH_AT: u64 = 25;
    let ckpt =
        std::env::temp_dir().join(format!("fedasync-chaos-resume-{}.ckpt", std::process::id()));
    let _ = std::fs::remove_file(&ckpt);

    let mut cfg_a = plain_cfg(EPOCHS, EPOCHS / 4);
    {
        let sv = cfg_a.serving.as_mut().expect("serving block");
        sv.checkpoint_path = Some(ckpt.display().to_string());
        sv.checkpoint_every = 1;
    }
    cfg_a.chaos =
        Some(ChaosConfig { crash_at_version: Some(CRASH_AT), ..ChaosConfig::default() });
    cfg_a.validate().expect("phase A config");

    let listener_a = TcpListener::bind("127.0.0.1:0").expect("bind phase A");
    let cell = AddrCell::new(listener_a.local_addr().expect("phase A addr"));

    // Tracked resilient clients, shared across both server lives: they
    // redial through the cell and resume in-flight sequence numbers.
    let behavior = scenario::behavior_for(&cfg_a, CONF_DEVICES, CONF_SEED);
    let (gamma, rho) = (cfg_a.gamma, cfg_a.rho);
    let client_handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let behavior = Arc::clone(&behavior);
            let cell = cell.clone();
            std::thread::spawn(move || {
                let trainer = conformance_quad();
                let mut fleet = dummy_fleet(CONF_DEVICES, 7);
                let data = dummy_dataset();
                let loop_cfg = ClientLoop {
                    behavior: behavior.as_ref(),
                    devices: CONF_DEVICES,
                    epochs: EPOCHS as u64,
                    gamma,
                    rho,
                    seed: CONF_SEED + 100 * (c as u64 + 1),
                    deadline: Duration::from_secs(120),
                    client_id: c as u64 + 1,
                    max_push_attempts: 0,
                    chaos: None,
                };
                run_quad_client(cell, &trainer, &mut fleet, &data, &loop_cfg)
                    .unwrap_or_else(|e| panic!("client {c}: {e}"))
            })
        })
        .collect();

    // Phase A: serve until the injected crash aborts the engine.
    let stats_a = Arc::new(ServingStats::default());
    let (done_a, svc_a) = spawn_served(&cfg_a, listener_a, Arc::clone(&stats_a));
    let crash = done_a
        .recv_timeout(Duration::from_secs(120))
        .expect("phase A deadlocked before the injected crash");
    let err = crash.expect_err("phase A must abort at the injected crash");
    assert!(format!("{err}").contains("injected crash"), "unexpected phase A error: {err}");
    svc_a.join().expect("phase A service join");
    assert!(ckpt.exists(), "the crash left no checkpoint behind");

    // Phase B: resume from the checkpoint on a fresh port, repoint the
    // swarm, finish the run.  This must start inside the clients' redial
    // patience window (~2s), which binding a socket comfortably is.
    let mut cfg_b = cfg_a.clone();
    cfg_b.chaos = None;
    cfg_b.serving.as_mut().expect("serving block").resume = true;
    cfg_b.validate().expect("phase B config");
    let listener_b = TcpListener::bind("127.0.0.1:0").expect("bind phase B");
    cell.set(listener_b.local_addr().expect("phase B addr"));
    let stats_b = Arc::new(ServingStats::default());
    let (done_b, svc_b) = spawn_served(&cfg_b, listener_b, Arc::clone(&stats_b));
    let log = done_b
        .recv_timeout(Duration::from_secs(180))
        .expect("resumed engine deadlocked")
        .expect("resumed run failed");
    svc_b.join().expect("phase B service join");
    let reports: Vec<ClientReport> =
        client_handles.into_iter().map(|h| h.join().expect("client join")).collect();

    let last = log.rows.last().expect("rows");
    assert!(last.epoch >= EPOCHS, "resumed run stopped early at {}", last.epoch);
    // The conservation law, across a crash: every version increment was
    // acked to exactly one client, in exactly one server life.
    let applied: u64 = reports.iter().map(|r| r.applied).sum();
    assert_eq!(
        applied,
        last.epoch as u64,
        "applied acks must re-derive the final version across the crash \
         (an update was lost or applied twice)"
    );
    // The ack dropped at the crash was re-offered against phase B and
    // answered from the *restored* dedup table, not re-applied.
    let deduped_b = stats_b.deduped.load(Ordering::Relaxed);
    assert!(deduped_b >= 1, "the in-flight update was never replayed from the checkpoint");
    // The fleet actually survived a server death: someone redialed.
    let reconnects: u64 = reports.iter().map(|r| r.reconnects).sum();
    assert!(reconnects >= 1, "no client ever reconnected across the restart");

    // Same problem, no crash: the resumed trajectory's final loss must
    // land in the shared band (recovery, not just completion).
    let (clean_log, _, _) = run_chaos_loopback(
        &plain_cfg(EPOCHS, EPOCHS / 4),
        None,
        CLIENTS,
        Duration::from_secs(120),
        Duration::from_secs(180),
    );
    let resumed = last.test_loss;
    let clean = clean_log.rows.last().expect("rows").test_loss;
    assert!(resumed.is_finite() && clean.is_finite(), "non-finite final losses");
    let lo = resumed.min(clean);
    let hi = resumed.max(clean);
    assert!(
        hi <= lo.max(1e-3) * 100.0,
        "crash/resume final loss diverged from the uninterrupted run: {resumed} vs {clean}"
    );

    let _ = std::fs::remove_file(&ckpt);
}

// ------------------------------------------------------- conformance soak

fn faulted_conformance_case(preset_file: &str) {
    // Drop/delay-only plan (no stream-killing faults), both sides of
    // every socket: lost requests and lost acks become retries under the
    // exactly-once protocol, so the run must still land inside the same
    // conformance band as the in-process threaded driver.
    let ch = ChaosConfig {
        seed: 7,
        delay_prob: 0.10,
        delay_ms: 1,
        drop_prob: 0.03,
        ..ChaosConfig::default()
    };

    let mut cfg = preset_cfg(preset_file);
    cfg.chaos = Some(ch.clone());
    cfg.validate().expect("faulted conformance config");
    let plan = FaultPlan::compile(&ch);
    let (served, reports, stats) = run_chaos_loopback(
        &cfg,
        Some(plan),
        CLIENTS,
        Duration::from_secs(150),
        Duration::from_secs(240),
    );

    let mut clean = cfg.clone();
    clean.chaos = None;
    let threaded = run_threaded_baseline(&clean);
    assert_conformant(preset_file, &served, &threaded);

    // Exactly-once accounting under frame loss: clients may miss acks
    // they were owed at shutdown (the retry has nowhere to go), but can
    // never observe more applies than the model has version increments.
    let applied: u64 = reports.iter().map(|r| r.applied).sum();
    let last = served.rows.last().expect("rows").epoch as u64;
    assert!(applied <= last, "{preset_file}: {applied} applied acks for {last} versions");
    assert!(applied > 0, "{preset_file}: no client ever observed an applied ack");
    // The server answered every admitted update it didn't crash on.
    let ld = Ordering::Relaxed;
    let (adm, ack, shed) = (stats.admitted.load(ld), stats.acked.load(ld), stats.shed.load(ld));
    assert!(ack + shed >= adm, "{preset_file}: admitted updates left unanswered");
}

#[test]
fn faulted_loopback_conforms_on_straggler_preset() {
    faulted_conformance_case("scenario_straggler.toml");
}

#[test]
fn faulted_loopback_conforms_on_churn_preset() {
    faulted_conformance_case("scenario_churn.toml");
}

// ------------------------------------------------------- dedup property

#[test]
fn retried_pushes_are_replayed_not_reapplied() {
    // One tracked client drives every epoch by hand and storms each
    // update's sequence number after the ack: every retry must come back
    // byte-identical to the original ack, from the dedup table, with the
    // model version pinned in place.
    const EPOCHS: usize = 30;
    let cfg = plain_cfg(EPOCHS, EPOCHS / 2);
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr");
    let stats = Arc::new(ServingStats::default());
    let (done_rx, svc) = spawn_served(&cfg, listener, Arc::clone(&stats));

    let opts = ClientOpts {
        client_id: 77,
        chaos: None,
        reply_timeout: Some(Duration::from_secs(5)),
    };
    let mut client = SwarmClient::connect_with(&addr, opts).expect("connect");
    let mut applied_acks: u64 = 0;
    let mut storms: u64 = 0;
    loop {
        // After the final ack the server tears down, so a failed pull is
        // the normal end of the conversation.
        let (tau, params) = match client.pull() {
            Ok(snap) => snap,
            Err(_) => break,
        };
        if tau >= EPOCHS as u64 {
            break;
        }
        let device = (tau % CONF_DEVICES as u64) as u32;
        let loss = 1.0f32;
        let outcome = client.push(device, tau, loss, params.clone()).expect("push");
        let PushOutcome::Acked { version, applied } = outcome else {
            panic!("the only client in the world was shed: {outcome:?}");
        };
        assert!(applied, "a fresh update from the only client must apply");
        applied_acks += 1;
        // Storm only while the server is guaranteed alive (the ack that
        // reaches the epoch target triggers teardown).
        if version < EPOCHS as u64 {
            for _ in 0..2 {
                let replay = client.retry_push(device, tau, loss, params.clone()).expect("retry");
                assert_eq!(
                    replay,
                    PushOutcome::Acked { version, applied: true },
                    "a replayed ack must be identical to the original"
                );
                storms += 1;
            }
            let status = client.status().expect("status round trip");
            assert_eq!(status.version, version, "a retry storm advanced the model");
        }
    }
    drop(client);
    let log = done_rx
        .recv_timeout(Duration::from_secs(120))
        .expect("served engine deadlocked")
        .expect("served run failed");
    svc.join().expect("native service join");

    let last = log.rows.last().expect("rows");
    assert_eq!(last.epoch, EPOCHS, "every distinct update applies exactly once");
    assert_eq!(applied_acks, EPOCHS as u64, "one applied ack per distinct update");
    let ld = Ordering::Relaxed;
    assert_eq!(
        stats.deduped.load(ld),
        storms,
        "every retry must be answered from the dedup table, none applied"
    );
    assert_eq!(
        stats.acked.load(ld),
        EPOCHS as u64,
        "the engine resolved exactly one ack per distinct update"
    );
}

// --------------------------------------------------- backoff termination

#[test]
fn attempt_cap_terminates_retry_loops_under_persistent_shed() {
    // A stub server that sheds every update, forever.  The client's
    // attempt cap must turn each update into a bounded retry ladder —
    // exactly `max_push_attempts` sheds, then the update is abandoned and
    // counted — instead of an unbounded backoff loop.
    const CAP: u32 = 4;
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind stub");
    let addr = listener.local_addr().expect("stub addr");
    let stub = std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().expect("stub accept");
        let mut reader = FrameReader::new();
        let mut scratch = Vec::new();
        let mut sheds: u64 = 0;
        loop {
            match reader.read_frame(&mut stream) {
                Ok(Some(Frame::PullModel)) => {
                    let snap = Frame::ModelSnapshot { version: 0, params: vec![0.0; 6] };
                    if write_frame(&mut stream, &snap, &mut scratch).is_err() {
                        break;
                    }
                }
                Ok(Some(Frame::ClientUpdate { .. })) => {
                    sheds += 1;
                    if write_frame(&mut stream, &Frame::Shed { retry_after_ms: 1 }, &mut scratch)
                        .is_err()
                    {
                        break;
                    }
                }
                Ok(Some(_)) => break,
                Ok(None) => continue,
                Err(_) => break, // client hung up: done
            }
        }
        sheds
    });

    let cfg = plain_cfg(CONF_EPOCHS, CONF_EPOCHS / 4);
    let behavior = scenario::behavior_for(&cfg, CONF_DEVICES, CONF_SEED);
    let trainer = conformance_quad();
    let mut fleet = dummy_fleet(CONF_DEVICES, 7);
    let data = dummy_dataset();
    let loop_cfg = ClientLoop {
        behavior: behavior.as_ref(),
        devices: CONF_DEVICES,
        epochs: CONF_EPOCHS as u64,
        gamma: cfg.gamma,
        rho: cfg.rho,
        seed: 9,
        deadline: Duration::from_secs(4),
        client_id: 5,
        max_push_attempts: CAP,
        chaos: None,
    };
    let report =
        run_quad_client(addr, &trainer, &mut fleet, &data, &loop_cfg).expect("client loop");
    let stub_sheds = stub.join().expect("stub join");

    assert!(report.abandoned >= 1, "no update was ever abandoned: {report:?}");
    assert_eq!(report.acked, 0, "the stub never acks, yet the client recorded acks");
    assert_eq!(report.pushed, 0, "pushed counts accepted updates only");
    // The cap is exact per abandoned update; the deadline may interrupt
    // one final ladder partway.
    assert!(
        report.shed >= report.abandoned * u64::from(CAP),
        "an update was abandoned after fewer than {CAP} attempts: {report:?}"
    );
    assert!(
        stub_sheds >= report.shed,
        "client observed more sheds ({}) than the server sent ({stub_sheds})",
        report.shed
    );
}

// ------------------------------------------------------- hostile smoke

#[test]
fn hostile_fault_plan_cannot_wedge_or_overcount() {
    // Every fault type armed at low rates on both sides: resets and
    // truncations kill streams mid-frame, duplicated frames desync the
    // reply stream, bit flips feed the decoder garbage.  Resilient
    // clients absorb all of it by redialing; the run must still reach its
    // target, and the exactly-once bound must hold.
    let ch = ChaosConfig {
        seed: 11,
        delay_prob: 0.05,
        delay_ms: 1,
        drop_prob: 0.02,
        reset_prob: 0.01,
        truncate_prob: 0.01,
        duplicate_prob: 0.02,
        corrupt_prob: 0.01,
        ..ChaosConfig::default()
    };

    const EPOCHS: usize = 40;
    let mut cfg = plain_cfg(EPOCHS, EPOCHS / 4);
    cfg.chaos = Some(ch.clone());
    cfg.validate().expect("hostile chaos config");
    let plan = FaultPlan::compile(&ch);
    let (log, reports, stats) = run_chaos_loopback(
        &cfg,
        Some(plan),
        CLIENTS,
        Duration::from_secs(150),
        Duration::from_secs(240),
    );

    let last = log.rows.last().expect("rows");
    assert!(last.epoch >= EPOCHS, "hostile plan stalled the run at {}", last.epoch);
    let applied: u64 = reports.iter().map(|r| r.applied).sum();
    assert!(applied <= last.epoch as u64, "more applied acks than version increments");
    assert!(applied > 0, "no update ever got through the fault plan");
    assert!(
        stats.acked.load(Ordering::Relaxed) >= applied,
        "server acked fewer than clients observed"
    );
}
