//! Integration: rust runtime ⇄ AOT artifacts (requires `make artifacts`).
//!
//! These tests exercise the full FFI plumbing: HLO text load, PJRT compile,
//! literal conversion, tuple unwrap — against the real `mlp_synth` model.
//! Numerics are cross-checked against native-rust recomputation where the
//! math is simple (mix), and against behavioural properties (loss descent,
//! step/epoch composition) where it is not.

use fedasync::runtime::{try_load_runtime, EpochBatch, ModelRuntime};
use fedasync::util::rng::Rng;

/// `None` ⇒ skip (shared policy in `fedasync::runtime::try_load_runtime`).
fn runtime() -> Option<ModelRuntime> {
    try_load_runtime("mlp_synth")
}

fn random_batch(rt: &ModelRuntime, rng: &mut Rng) -> EpochBatch {
    let m = &rt.manifest;
    let n = m.local_iters * m.batch_size;
    let images = (0..n * rt.input_size())
        .map(|_| rng.gaussian() as f32)
        .collect();
    let labels = (0..n)
        .map(|_| rng.index(m.num_classes) as i32)
        .collect();
    EpochBatch { images, labels }
}

#[test]
fn loads_and_reports_dimensions() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.manifest.model, "mlp_synth");
    assert!(rt.param_count() > 1000);
    assert_eq!(rt.input_size(), 32);
    assert_eq!(rt.manifest.batch_size, 50);
    assert_eq!(rt.manifest.local_iters, 10);
}

#[test]
fn init_params_deterministic_and_distinct_per_seed() {
    let Some(rt) = runtime() else { return };
    let a = rt.init_params(0).unwrap();
    let b = rt.init_params(0).unwrap();
    let c = rt.init_params(1).unwrap();
    assert_eq!(a.len(), rt.param_count());
    assert_eq!(a, b);
    assert_ne!(a, c);
    assert!(a.iter().all(|x| x.is_finite()));
}

#[test]
fn mix_matches_native_formula() {
    let Some(rt) = runtime() else { return };
    let p = rt.param_count();
    let mut rng = Rng::seed_from(1);
    let x: Vec<f32> = (0..p).map(|_| rng.gaussian() as f32).collect();
    let y: Vec<f32> = (0..p).map(|_| rng.gaussian() as f32).collect();
    for alpha in [0.0f32, 0.3, 0.75, 1.0] {
        let got = rt.mix(&x, &y, alpha).unwrap();
        for i in (0..p).step_by(97) {
            let want = (1.0 - alpha) * x[i] + alpha * y[i];
            assert!(
                (got[i] - want).abs() <= 1e-5 * (1.0 + want.abs()),
                "alpha={alpha} i={i}: got {} want {want}",
                got[i]
            );
        }
    }
}

#[test]
fn train_epoch_descends_on_fixed_batch() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::seed_from(2);
    let batch = random_batch(&rt, &mut rng);
    let mut params = rt.init_params(0).unwrap();
    let (_, first_loss) = rt.train_epoch(&params, None, &batch, 0.1, 0.0).unwrap();
    let mut last_loss = first_loss;
    for _ in 0..5 {
        let (p, loss) = rt.train_epoch(&params, None, &batch, 0.1, 0.0).unwrap();
        params = p;
        last_loss = loss;
    }
    assert!(
        last_loss < first_loss * 0.8,
        "no descent: first={first_loss} last={last_loss}"
    );
}

#[test]
fn epoch_equals_composed_steps() {
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    let mut rng = Rng::seed_from(3);
    let batch = random_batch(&rt, &mut rng);
    let params0 = rt.init_params(1).unwrap();
    let gamma = 0.05f32;

    let (epoch_params, _) = rt.train_epoch(&params0, None, &batch, gamma, 0.0).unwrap();

    let isz = rt.input_size();
    let b = m.batch_size;
    let mut seq = params0.clone();
    for h in 0..m.local_iters {
        let img = &batch.images[h * b * isz..(h + 1) * b * isz];
        let lbl = &batch.labels[h * b..(h + 1) * b];
        let (p, _) = rt.train_step(&seq, None, img, lbl, gamma, 0.0).unwrap();
        seq = p;
    }
    let max_diff = epoch_params
        .iter()
        .zip(&seq)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "epoch vs steps max diff {max_diff}");
}

#[test]
fn prox_keeps_params_nearer_anchor() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::seed_from(4);
    let batch = random_batch(&rt, &mut rng);
    let anchor = rt.init_params(0).unwrap();
    let gamma = 0.1f32;

    let (sgd_p, _) = rt.train_epoch(&anchor, None, &batch, gamma, 0.0).unwrap();
    let (prox_p, _) = rt
        .train_epoch(&anchor, Some(&anchor), &batch, gamma, 5.0)
        .unwrap();
    let dist = |p: &[f32]| -> f64 {
        p.iter()
            .zip(&anchor)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    };
    assert!(dist(&prox_p) < dist(&sgd_p));
}

#[test]
fn eval_returns_chance_accuracy_at_init_on_random_labels() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::seed_from(5);
    let n = rt.manifest.eval_batch * 2;
    let images: Vec<f32> = (0..n * rt.input_size()).map(|_| rng.gaussian() as f32).collect();
    let labels: Vec<i32> = (0..n).map(|_| rng.index(10) as i32).collect();
    let params = rt.init_params(0).unwrap();
    let m = rt.eval(&params, &images, &labels).unwrap();
    assert_eq!(m.samples, n);
    assert!(m.loss > 1.0 && m.loss < 5.0, "loss={}", m.loss);
    assert!(m.accuracy < 0.35, "acc={}", m.accuracy);
}

#[test]
fn shape_errors_are_reported_not_panicked() {
    let Some(rt) = runtime() else { return };
    let params = rt.init_params(0).unwrap();
    // Wrong param length.
    assert!(rt.mix(&params[1..], &params, 0.5).is_err());
    // Wrong batch size.
    let bad = EpochBatch { images: vec![0.0; 7], labels: vec![0; 3] };
    assert!(rt.train_epoch(&params, None, &bad, 0.1, 0.0).is_err());
    // Eval with too few samples.
    assert!(rt.eval(&params, &[0.0; 32], &[0]).is_err());
}

#[test]
fn call_counters_track_executions() {
    let Some(rt) = runtime() else { return };
    let params = rt.init_params(0).unwrap();
    let _ = rt.mix(&params, &params, 0.5).unwrap();
    let _ = rt.mix(&params, &params, 0.5).unwrap();
    assert_eq!(rt.call_counts().get("mix"), Some(&2));
}
