//! Integration: full training runs through the PJRT-backed model.
//!
//! These are the end-to-end checks that all three layers compose: synthetic
//! federated data (rust) → AOT-compiled JAX/Pallas local training (PJRT) →
//! asynchronous coordination and mixing (rust).  Runs are kept short; the
//! full-scale curves live in `repro figure` / EXPERIMENTS.md.

use fedasync::config::presets::{named, Scale};
use fedasync::config::{Algo, ExperimentConfig, LocalUpdate, StalenessFn};
use fedasync::experiment::runner;
use fedasync::runtime::{model_dir, try_load_runtime, ModelRuntime};

/// `None` ⇒ skip (shared policy in `fedasync::runtime::try_load_runtime`).
fn runtime() -> Option<ModelRuntime> {
    try_load_runtime("mlp_synth")
}

fn short_cfg(algo: Algo) -> ExperimentConfig {
    let mut cfg = named("fedasync", Scale::Fast).unwrap();
    cfg.algo = algo;
    cfg.epochs = 120;
    cfg.repeats = 1;
    cfg.eval_every = 30;
    cfg.federation.devices = 20;
    cfg.federation.samples_per_device = 100;
    cfg.federation.test_samples = 512;
    if matches!(cfg.algo, Algo::FedAvg { .. } | Algo::Sgd) {
        cfg.local_update = LocalUpdate::Sgd;
    }
    cfg
}

#[test]
fn fedasync_learns_on_real_model() {
    let Some(rt) = runtime() else { return };
    let cfg = short_cfg(Algo::FedAsync);
    let log = runner::run(&rt, &cfg).unwrap();
    let first = &log.rows[0];
    let last = log.rows.last().unwrap();
    assert!(first.test_acc < 0.2, "init acc {}", first.test_acc);
    assert!(last.test_acc > 0.35, "final acc {}", last.test_acc);
    assert!(last.test_loss < first.test_loss);
    assert_eq!(last.gradients, 120 * 10);
    assert_eq!(last.comms, 240);
}

#[test]
fn fedavg_learns_on_real_model() {
    let Some(rt) = runtime() else { return };
    let cfg = short_cfg(Algo::FedAvg { k: 5 });
    let log = runner::run(&rt, &cfg).unwrap();
    let last = log.rows.last().unwrap();
    assert!(last.test_acc > 0.4, "final acc {}", last.test_acc);
    assert_eq!(last.gradients, 120 * 5 * 10);
    assert_eq!(last.comms, 120 * 10);
}

#[test]
fn sgd_beats_fedavg_per_gradient() {
    // The paper's headline ordering at small staleness (per gradient):
    // SGD ≥ FedAsync ≥ FedAvg.
    let Some(rt) = runtime() else { return };
    let sgd = runner::run(&rt, &short_cfg(Algo::Sgd)).unwrap();
    let fedasync = runner::run(&rt, &short_cfg(Algo::FedAsync)).unwrap();
    let fedavg = runner::run(&rt, &short_cfg(Algo::FedAvg { k: 5 })).unwrap();
    // Compare best accuracy reached within SGD's gradient budget (1200).
    let budget = sgd.rows.last().unwrap().gradients;
    let acc_at = |log: &fedasync::federated::metrics::MetricsLog| {
        log.rows
            .iter()
            .filter(|r| r.gradients <= budget)
            .map(|r| r.test_acc)
            .fold(0.0f64, f64::max)
    };
    let (a_sgd, a_async, a_avg) = (acc_at(&sgd), acc_at(&fedasync), acc_at(&fedavg));
    assert!(
        a_sgd >= a_async - 0.05,
        "SGD {a_sgd} should be >= FedAsync {a_async} per gradient"
    );
    assert!(
        a_async > a_avg + 0.02,
        "FedAsync {a_async} should beat FedAvg {a_avg} per gradient"
    );
}

#[test]
fn option2_prox_no_worse_than_option1_under_staleness() {
    let Some(rt) = runtime() else { return };
    let mut opt1 = short_cfg(Algo::FedAsync);
    opt1.local_update = LocalUpdate::Sgd;
    opt1.staleness.max = 16;
    let mut opt2 = short_cfg(Algo::FedAsync);
    opt2.local_update = LocalUpdate::Prox;
    opt2.rho = 0.05;
    opt2.staleness.max = 16;
    let log1 = runner::run(&rt, &opt1).unwrap();
    let log2 = runner::run(&rt, &opt2).unwrap();
    let a1 = log1.rows.last().unwrap().test_acc;
    let a2 = log2.rows.last().unwrap().test_acc;
    // Regularization must not catastrophically hurt (and usually helps).
    assert!(a2 > a1 - 0.08, "opt1={a1} opt2={a2}");
}

#[test]
fn adaptive_alpha_helps_at_large_staleness() {
    let Some(rt) = runtime() else { return };
    let mut plain = short_cfg(Algo::FedAsync);
    plain.staleness.max = 16;
    plain.alpha = 0.9; // stress: large α is where adaptivity matters (fig 9/10)
    let mut poly = plain.clone();
    poly.staleness.func = StalenessFn::Poly { a: 0.5 };
    let log_plain = runner::run(&rt, &plain).unwrap();
    let log_poly = runner::run(&rt, &poly).unwrap();
    let a_plain = log_plain.rows.last().unwrap().test_acc;
    let a_poly = log_poly.rows.last().unwrap().test_acc;
    assert!(
        a_poly > a_plain - 0.05,
        "poly adaptive {a_poly} vs plain {a_plain}"
    );
    // And its effective alpha really is smaller.
    let mean_alpha = |log: &fedasync::federated::metrics::MetricsLog| {
        let xs: Vec<f64> = log.rows.iter().skip(1).map(|r| r.alpha_eff).collect();
        xs.iter().sum::<f64>() / xs.len() as f64
    };
    assert!(mean_alpha(&log_poly) < mean_alpha(&log_plain));
}

#[test]
fn threaded_server_trains_end_to_end() {
    // The Figure-1 architecture: scheduler ∥ workers ∥ updater on real
    // threads, PJRT behind a compute-service thread.  (The PJRT-free
    // topology tests live in `server_core.rs`.)
    if runtime().is_none() {
        return;
    }
    let mut cfg = short_cfg(Algo::FedAsync);
    cfg.mode = fedasync::config::ExecMode::Threads;
    cfg.epochs = 40;
    cfg.eval_every = 20;
    cfg.worker_threads = 3;
    cfg.max_inflight = 4;
    let log =
        fedasync::coordinator::server::run_threaded(model_dir("mlp_synth"), &cfg, 1).unwrap();
    let last = log.rows.last().unwrap();
    assert!(last.epoch >= 40, "reached epoch {}", last.epoch);
    assert!(last.test_loss.is_finite());
    assert!(last.staleness >= 1.0, "threaded staleness {}", last.staleness);
    // Loss should at least move from the init row.
    assert!(last.test_loss < log.rows[0].test_loss);
}

#[test]
fn emergent_vs_sampled_staleness_same_ballpark() {
    // DESIGN.md claims the paper's sampled-staleness protocol is a faithful
    // stand-in for emergent asynchrony; both must learn comparably.
    let Some(rt) = runtime() else { return };
    let cfg = short_cfg(Algo::FedAsync);
    let sampled = runner::run(&rt, &cfg).unwrap();
    let emergent = runner::run_once_emergent(&rt, &cfg, 0, 8).unwrap();
    let a_s = sampled.rows.last().unwrap().test_acc;
    let a_e = emergent.rows.last().unwrap().test_acc;
    assert!((a_s - a_e).abs() < 0.2, "sampled={a_s} emergent={a_e}");
}
