//! Integration: full training runs through the PJRT-backed model.
//!
//! These are the end-to-end checks that all three layers compose: synthetic
//! federated data (rust) → AOT-compiled JAX/Pallas local training (PJRT) →
//! asynchronous coordination and mixing (rust).  Runs are kept short; the
//! full-scale curves live in `repro figure` / EXPERIMENTS.md.

use std::sync::mpsc;

use fedasync::analysis::quadratic::{dummy_dataset, dummy_fleet, QuadraticProblem};
use fedasync::config::presets::{named, Scale};
use fedasync::config::{Algo, ExperimentConfig, LocalUpdate, StalenessFn};
use fedasync::coordinator::server::{run_server_core, serve_native, ComputeJob};
use fedasync::coordinator::virtual_mode::{run_fedasync, StalenessSource};
use fedasync::coordinator::Trainer;
use fedasync::experiment::runner;
use fedasync::federated::data::FederatedData;
use fedasync::federated::metrics::MetricsLog;
use fedasync::runtime::{model_dir, try_load_runtime, ModelRuntime};
use fedasync::scenario;

/// `None` ⇒ skip (shared policy in `fedasync::runtime::try_load_runtime`).
fn runtime() -> Option<ModelRuntime> {
    try_load_runtime("mlp_synth")
}

fn short_cfg(algo: Algo) -> ExperimentConfig {
    let mut cfg = named("fedasync", Scale::Fast).unwrap();
    cfg.algo = algo;
    cfg.epochs = 120;
    cfg.repeats = 1;
    cfg.eval_every = 30;
    cfg.federation.devices = 20;
    cfg.federation.samples_per_device = 100;
    cfg.federation.test_samples = 512;
    if matches!(cfg.algo, Algo::FedAvg { .. } | Algo::Sgd) {
        cfg.local_update = LocalUpdate::Sgd;
    }
    cfg
}

#[test]
fn fedasync_learns_on_real_model() {
    let Some(rt) = runtime() else { return };
    let cfg = short_cfg(Algo::FedAsync);
    let log = runner::run(&rt, &cfg).unwrap();
    let first = &log.rows[0];
    let last = log.rows.last().unwrap();
    assert!(first.test_acc < 0.2, "init acc {}", first.test_acc);
    assert!(last.test_acc > 0.35, "final acc {}", last.test_acc);
    assert!(last.test_loss < first.test_loss);
    assert_eq!(last.gradients, 120 * 10);
    assert_eq!(last.comms, 240);
}

#[test]
fn fedavg_learns_on_real_model() {
    let Some(rt) = runtime() else { return };
    let cfg = short_cfg(Algo::FedAvg { k: 5 });
    let log = runner::run(&rt, &cfg).unwrap();
    let last = log.rows.last().unwrap();
    assert!(last.test_acc > 0.4, "final acc {}", last.test_acc);
    assert_eq!(last.gradients, 120 * 5 * 10);
    assert_eq!(last.comms, 120 * 10);
}

#[test]
fn sgd_beats_fedavg_per_gradient() {
    // The paper's headline ordering at small staleness (per gradient):
    // SGD ≥ FedAsync ≥ FedAvg.
    let Some(rt) = runtime() else { return };
    let sgd = runner::run(&rt, &short_cfg(Algo::Sgd)).unwrap();
    let fedasync = runner::run(&rt, &short_cfg(Algo::FedAsync)).unwrap();
    let fedavg = runner::run(&rt, &short_cfg(Algo::FedAvg { k: 5 })).unwrap();
    // Compare best accuracy reached within SGD's gradient budget (1200).
    let budget = sgd.rows.last().unwrap().gradients;
    let acc_at = |log: &fedasync::federated::metrics::MetricsLog| {
        log.rows
            .iter()
            .filter(|r| r.gradients <= budget)
            .map(|r| r.test_acc)
            .fold(0.0f64, f64::max)
    };
    let (a_sgd, a_async, a_avg) = (acc_at(&sgd), acc_at(&fedasync), acc_at(&fedavg));
    assert!(
        a_sgd >= a_async - 0.05,
        "SGD {a_sgd} should be >= FedAsync {a_async} per gradient"
    );
    assert!(
        a_async > a_avg + 0.02,
        "FedAsync {a_async} should beat FedAvg {a_avg} per gradient"
    );
}

#[test]
fn option2_prox_no_worse_than_option1_under_staleness() {
    let Some(rt) = runtime() else { return };
    let mut opt1 = short_cfg(Algo::FedAsync);
    opt1.local_update = LocalUpdate::Sgd;
    opt1.staleness.max = 16;
    let mut opt2 = short_cfg(Algo::FedAsync);
    opt2.local_update = LocalUpdate::Prox;
    opt2.rho = 0.05;
    opt2.staleness.max = 16;
    let log1 = runner::run(&rt, &opt1).unwrap();
    let log2 = runner::run(&rt, &opt2).unwrap();
    let a1 = log1.rows.last().unwrap().test_acc;
    let a2 = log2.rows.last().unwrap().test_acc;
    // Regularization must not catastrophically hurt (and usually helps).
    assert!(a2 > a1 - 0.08, "opt1={a1} opt2={a2}");
}

#[test]
fn adaptive_alpha_helps_at_large_staleness() {
    let Some(rt) = runtime() else { return };
    let mut plain = short_cfg(Algo::FedAsync);
    plain.staleness.max = 16;
    plain.alpha = 0.9; // stress: large α is where adaptivity matters (fig 9/10)
    let mut poly = plain.clone();
    poly.staleness.func = StalenessFn::Poly { a: 0.5 };
    let log_plain = runner::run(&rt, &plain).unwrap();
    let log_poly = runner::run(&rt, &poly).unwrap();
    let a_plain = log_plain.rows.last().unwrap().test_acc;
    let a_poly = log_poly.rows.last().unwrap().test_acc;
    assert!(
        a_poly > a_plain - 0.05,
        "poly adaptive {a_poly} vs plain {a_plain}"
    );
    // And its effective alpha really is smaller.
    let mean_alpha = |log: &fedasync::federated::metrics::MetricsLog| {
        let xs: Vec<f64> = log.rows.iter().skip(1).map(|r| r.alpha_eff).collect();
        xs.iter().sum::<f64>() / xs.len() as f64
    };
    assert!(mean_alpha(&log_poly) < mean_alpha(&log_plain));
}

#[test]
fn threaded_server_trains_end_to_end() {
    // The Figure-1 architecture: scheduler ∥ workers ∥ updater on real
    // threads, PJRT behind a compute-service thread.  (The PJRT-free
    // topology tests live in `server_core.rs`.)
    if runtime().is_none() {
        return;
    }
    let mut cfg = short_cfg(Algo::FedAsync);
    cfg.mode = fedasync::config::ExecMode::Threads;
    cfg.epochs = 40;
    cfg.eval_every = 20;
    cfg.worker_threads = 3;
    cfg.max_inflight = 4;
    let log =
        fedasync::coordinator::server::run_threaded(model_dir("mlp_synth"), &cfg, 1).unwrap();
    let last = log.rows.last().unwrap();
    assert!(last.epoch >= 40, "reached epoch {}", last.epoch);
    assert!(last.test_loss.is_finite());
    assert!(last.staleness >= 1.0, "threaded staleness {}", last.staleness);
    // Loss should at least move from the init row.
    assert!(last.test_loss < log.rows[0].test_loss);
}

// ---------------------------------------------------------------------
// Cross-mode scenario conformance (artifact-free: closed-form quadratic).
//
// For every shipped `configs/scenario_*.toml` preset, the sampled,
// emergent, and threaded executions consume the same `ClientBehavior`,
// so they must tell one story: every mode learns, final losses sit in a
// shared band, and the staleness histograms have overlapping supports.
// ---------------------------------------------------------------------

const CONF_DEVICES: usize = 16;
const CONF_EPOCHS: usize = 120;
const CONF_SEED: u64 = 1;

fn conformance_quad() -> QuadraticProblem {
    // Mild gradient noise gives every mode the same variance floor, which
    // keeps the cross-mode loss band meaningful.
    QuadraticProblem::new(CONF_DEVICES, 6, 0.5, 2.0, 2.0, 0.05, 5, 3)
}

/// Shrink a config to conformance-test size and normalize the knobs the
/// cross-mode loss band depends on.  Shared by the scenario suite and
/// the aggregator suite below, so their baselines stay in lockstep.
///
/// The α schedule is pinned flat and the staleness function to Poly:
/// the conformance bands are about the axis under test (population or
/// aggregation strategy), and Poly keeps every staleness level
/// learning, while e.g. Hinge would conflate the band with how hard
/// each mode's staleness distribution hits b.
fn conformance_shrink(cfg: &mut ExperimentConfig) {
    cfg.epochs = CONF_EPOCHS;
    cfg.eval_every = CONF_EPOCHS / 4;
    cfg.repeats = 1;
    cfg.seed = CONF_SEED;
    cfg.gamma = 0.05;
    cfg.alpha = 0.6;
    cfg.alpha_decay = 1.0;
    cfg.alpha_decay_at = usize::MAX;
    cfg.local_update = LocalUpdate::Sgd;
    cfg.staleness.func = StalenessFn::Poly { a: 0.5 };
    cfg.federation.devices = CONF_DEVICES;
    cfg.worker_threads = 3;
    cfg.max_inflight = 4;
}

/// Shrink a shipped scenario config to conformance-test size without
/// touching its scenario block or staleness cutoff policy.
fn conformance_cfg(path: &std::path::Path) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::from_toml_file(path)
        .unwrap_or_else(|e| panic!("{path:?}: {e}"));
    assert!(cfg.scenario.is_some(), "{path:?} must carry a [scenario] table");
    conformance_shrink(&mut cfg);
    cfg.validate().unwrap_or_else(|e| panic!("{path:?} shrunk: {e}"));
    cfg
}

fn run_conformance_mode(cfg: &ExperimentConfig, mode: &str) -> MetricsLog {
    let p = conformance_quad();
    match mode {
        "sampled" | "emergent" => {
            let data = FederatedData { train: dummy_dataset(), test: dummy_dataset() };
            let mut fleet = dummy_fleet(CONF_DEVICES, 5);
            let source = if mode == "sampled" {
                StalenessSource::Sampled { max: cfg.staleness.max }
            } else {
                // Match the threaded server's in-flight budget so the two
                // emergent-staleness executions see comparable overlap.
                StalenessSource::Emergent { inflight: cfg.max_inflight }
            };
            run_fedasync(&p, cfg, &data, &mut fleet, CONF_SEED, source)
                .unwrap_or_else(|e| panic!("{mode} run: {e}"))
        }
        "threaded" => {
            let init = p.init_params(CONF_SEED as usize).expect("init");
            let h = p.local_iters();
            let (job_tx, job_rx) = mpsc::channel::<ComputeJob>();
            let svc = std::thread::spawn(move || {
                serve_native(conformance_quad(), CONF_DEVICES, job_rx)
            });
            let behavior = scenario::behavior_for(cfg, CONF_DEVICES, CONF_SEED);
            let test = dummy_dataset();
            let log = run_server_core(cfg, CONF_SEED, &test, init, h, job_tx, behavior)
                .unwrap_or_else(|e| panic!("threaded run: {e}"));
            svc.join().expect("service join");
            log
        }
        other => panic!("unknown mode {other}"),
    }
}

#[test]
fn scenario_presets_conform_across_modes() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut preset_paths: Vec<std::path::PathBuf> = std::fs::read_dir(&dir)
        .expect("configs/ exists")
        .filter_map(|e| {
            let path = e.unwrap().path();
            let name = path.file_name()?.to_str()?.to_string();
            (name.starts_with("scenario_") && name.ends_with(".toml")).then_some(path)
        })
        .collect();
    preset_paths.sort();
    assert!(
        preset_paths.len() >= 3,
        "expected >= 3 shipped scenario presets, found {preset_paths:?}"
    );

    for path in &preset_paths {
        let cfg = conformance_cfg(path);
        let logs: Vec<(&str, MetricsLog)> = ["sampled", "emergent", "threaded"]
            .into_iter()
            .map(|m| (m, run_conformance_mode(&cfg, m)))
            .collect();

        // Every mode learns: the final loss clears a shared reduction bar.
        let mut finals = Vec::new();
        for (mode, log) in &logs {
            let first = log.rows.first().expect("rows").test_loss;
            let last = log.rows.last().expect("rows").test_loss;
            assert!(
                last.is_finite() && last < first * 0.5,
                "{path:?} {mode}: no learning ({first} -> {last})"
            );
            assert!(
                log.staleness_hist.total() > 0,
                "{path:?} {mode}: empty staleness histogram"
            );
            // Effective clients stay within the fleet and are reported.
            assert!(log
                .rows
                .iter()
                .all(|r| r.clients >= 1 && r.clients <= CONF_DEVICES));
            finals.push(last);
        }

        // Final losses sit in one band: the same scenario through three
        // executions must not diverge by orders of magnitude.
        let lo = finals.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = finals.iter().cloned().fold(0.0f64, f64::max);
        assert!(
            hi <= lo.max(1e-3) * 100.0,
            "{path:?}: cross-mode final losses diverged: {finals:?}"
        );

        // Staleness supports overlap pairwise: the population's staleness
        // signature survives the change of execution substrate.
        for i in 0..logs.len() {
            for j in i + 1..logs.len() {
                let a: std::collections::BTreeSet<u64> =
                    logs[i].1.staleness_hist.support().into_iter().collect();
                let b: std::collections::BTreeSet<u64> =
                    logs[j].1.staleness_hist.support().into_iter().collect();
                assert!(
                    a.intersection(&b).next().is_some(),
                    "{path:?}: {} and {} staleness supports are disjoint: {a:?} vs {b:?}",
                    logs[i].0,
                    logs[j].0
                );
            }
        }
    }
}

/// Dedicated truncated run of the scale-ceiling preset: the
/// `scenario_million` population (four tiers, deep churn, mid-run
/// burst, transport faults) over a fleet two orders of magnitude
/// larger than the generic conformance sweep above — big enough that
/// the SoA behavior arrays, the timer-wheel far-horizon path, and the
/// rejection-sampling assign loop all run in anger, yet bounded so the
/// CI scenario-smoke job clears its time budget.  Same conformance
/// story: all three executions learn, final losses share a band, and
/// staleness supports overlap pairwise.
#[test]
fn scenario_million_truncated_conforms_across_modes() {
    const DEVICES: usize = 1024;
    const EPOCHS: usize = 160;
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/scenario_million.toml");
    let mut cfg =
        ExperimentConfig::from_toml_file(&path).unwrap_or_else(|e| panic!("{path:?}: {e}"));
    assert!(cfg.scenario.is_some(), "{path:?} must carry a [scenario] table");
    conformance_shrink(&mut cfg);
    cfg.epochs = EPOCHS;
    cfg.eval_every = EPOCHS / 4;
    cfg.federation.devices = DEVICES;
    cfg.max_inflight = 8;
    cfg.validate().unwrap_or_else(|e| panic!("{path:?} truncated: {e}"));

    let p = QuadraticProblem::new(DEVICES, 6, 0.5, 2.0, 2.0, 0.05, 5, 3);
    let run = |mode: &str| -> MetricsLog {
        match mode {
            "sampled" | "emergent" => {
                let data = FederatedData { train: dummy_dataset(), test: dummy_dataset() };
                let mut fleet = dummy_fleet(DEVICES, 5);
                let source = if mode == "sampled" {
                    StalenessSource::Sampled { max: cfg.staleness.max }
                } else {
                    StalenessSource::Emergent { inflight: cfg.max_inflight }
                };
                run_fedasync(&p, &cfg, &data, &mut fleet, CONF_SEED, source)
                    .unwrap_or_else(|e| panic!("{mode} run: {e}"))
            }
            "threaded" => {
                let init = p.init_params(CONF_SEED as usize).expect("init");
                let h = p.local_iters();
                let (job_tx, job_rx) = mpsc::channel::<ComputeJob>();
                let svc = std::thread::spawn(move || {
                    serve_native(
                        QuadraticProblem::new(DEVICES, 6, 0.5, 2.0, 2.0, 0.05, 5, 3),
                        DEVICES,
                        job_rx,
                    )
                });
                let behavior = scenario::behavior_for(&cfg, DEVICES, CONF_SEED);
                let test = dummy_dataset();
                let log = run_server_core(&cfg, CONF_SEED, &test, init, h, job_tx, behavior)
                    .unwrap_or_else(|e| panic!("threaded run: {e}"));
                svc.join().expect("service join");
                log
            }
            other => panic!("unknown mode {other}"),
        }
    };

    let logs: Vec<(&str, MetricsLog)> =
        ["sampled", "emergent", "threaded"].into_iter().map(|m| (m, run(m))).collect();

    let mut finals = Vec::new();
    for (mode, log) in &logs {
        let first = log.rows.first().expect("rows").test_loss;
        let last = log.rows.last().expect("rows").test_loss;
        assert!(
            last.is_finite() && last < first * 0.5,
            "scenario_million {mode}: no learning ({first} -> {last})"
        );
        assert!(
            log.staleness_hist.total() > 0,
            "scenario_million {mode}: empty staleness histogram"
        );
        assert!(log.rows.iter().all(|r| r.clients >= 1 && r.clients <= DEVICES));
        finals.push(last);
    }
    let lo = finals.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = finals.iter().cloned().fold(0.0f64, f64::max);
    assert!(
        hi <= lo.max(1e-3) * 100.0,
        "scenario_million: cross-mode final losses diverged: {finals:?}"
    );
    for i in 0..logs.len() {
        for j in i + 1..logs.len() {
            let a: std::collections::BTreeSet<u64> =
                logs[i].1.staleness_hist.support().into_iter().collect();
            let b: std::collections::BTreeSet<u64> =
                logs[j].1.staleness_hist.support().into_iter().collect();
            assert!(
                a.intersection(&b).next().is_some(),
                "scenario_million: {} and {} staleness supports are disjoint: {a:?} vs {b:?}",
                logs[i].0,
                logs[j].0
            );
        }
    }
}

// ---------------------------------------------------------------------
// Aggregator × driver conformance (artifact-free).
//
// The aggregation layer and the time drivers are orthogonal axes of the
// engine: every strategy must run through every driver and tell one
// story.  This is the aggregation-layer counterpart of the scenario
// conformance suite above.
// ---------------------------------------------------------------------

/// Conformance-sized config with no scenario: the axis under test here
/// is the aggregator, against the uniform baseline population.
fn aggregator_conformance_cfg(agg: fedasync::config::AggregatorConfig) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("agg_{}", agg.name());
    conformance_shrink(&mut cfg);
    cfg.staleness.max = 8;
    cfg.aggregator = agg;
    cfg.validate().unwrap_or_else(|e| panic!("aggregator conformance cfg: {e}"));
    cfg
}

#[test]
fn aggregators_conform_across_modes() {
    use fedasync::config::AggregatorConfig;
    let strategies = [
        AggregatorConfig::FedAsync,
        AggregatorConfig::Buffered { k: 4 },
        AggregatorConfig::DistanceAdaptive { clamp_lo: 0.2, clamp_hi: 2.0 },
    ];
    for agg in strategies {
        let cfg = aggregator_conformance_cfg(agg);
        let logs: Vec<(&str, MetricsLog)> = ["sampled", "emergent", "threaded"]
            .into_iter()
            .map(|m| (m, run_conformance_mode(&cfg, m)))
            .collect();

        let mut finals = Vec::new();
        for (mode, log) in &logs {
            let first = log.rows.first().expect("rows").test_loss;
            let last = log.rows.last().expect("rows");
            assert!(
                last.test_loss.is_finite() && last.test_loss < first * 0.5,
                "{agg:?} {mode}: no learning ({first} -> {})",
                last.test_loss
            );
            assert!(
                log.staleness_hist.total() > 0,
                "{agg:?} {mode}: empty staleness histogram"
            );
            // The applied/buffered columns must match the strategy's
            // semantics in every mode.
            match agg {
                AggregatorConfig::Buffered { k } => {
                    assert!(last.buffered > 0, "{agg:?} {mode}: nothing buffered");
                    assert!(
                        last.applied * k as u64 >= last.buffered
                            && last.buffered >= last.applied.saturating_sub(1) * k as u64,
                        "{agg:?} {mode}: applied={} buffered={} inconsistent with k={k}",
                        last.applied,
                        last.buffered
                    );
                }
                _ => {
                    assert_eq!(
                        last.buffered, 0,
                        "{agg:?} {mode}: non-buffering strategy buffered updates"
                    );
                    assert!(
                        last.applied as usize >= cfg.epochs,
                        "{agg:?} {mode}: applied {} < epochs",
                        last.applied
                    );
                }
            }
            finals.push(last.test_loss);
        }

        // One loss band across the three executions of the same strategy.
        let lo = finals.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = finals.iter().cloned().fold(0.0f64, f64::max);
        assert!(
            hi <= lo.max(1e-3) * 100.0,
            "{agg:?}: cross-mode final losses diverged: {finals:?}"
        );
    }
}

#[test]
fn buffered_flush_on_drain_catches_the_tail() {
    // 10 epochs at k=4 in the sampled protocol: 10 accepted updates =
    // 2 in-stream commits + a 2-update tail the end-of-run flush must
    // commit (versions 3), so no accepted update is lost at shutdown.
    use fedasync::config::AggregatorConfig;
    let mut cfg = aggregator_conformance_cfg(AggregatorConfig::Buffered { k: 4 });
    cfg.epochs = 10;
    cfg.eval_every = 5;
    let log = run_conformance_mode(&cfg, "sampled");
    let last = log.rows.last().expect("rows");
    assert_eq!(last.buffered, 10, "all 10 accepted updates absorbed");
    assert_eq!(last.applied, 3, "2 in-stream commits + 1 drain flush");
}

#[test]
fn scenario_churn_shows_up_in_clients_column() {
    // The churn preset's effective-client count must actually move.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let cfg = conformance_cfg(&dir.join("scenario_churn.toml"));
    let log = run_conformance_mode(&cfg, "sampled");
    let first = log.rows.first().unwrap().clients;
    let mid = log.rows[log.rows.len() / 2].clients;
    assert_eq!(first, CONF_DEVICES, "full fleet at t=0");
    assert!(
        mid < first,
        "churn never shrank the effective fleet: {first} -> {mid}"
    );
}

#[test]
fn sampled_mode_survives_heavy_duplication() {
    // Regression: duplicate deliveries push the store version *ahead* of
    // the task counter, so the historical anchor read must clamp to the
    // ring's retained window — pre-fix this panicked on `ModelStore::get`.
    let mut cfg = ExperimentConfig::default();
    cfg.epochs = 100;
    cfg.eval_every = 50;
    cfg.repeats = 1;
    cfg.gamma = 0.05;
    cfg.alpha = 0.5;
    cfg.alpha_decay = 1.0;
    cfg.alpha_decay_at = usize::MAX;
    cfg.local_update = LocalUpdate::Sgd;
    cfg.staleness.max = 4;
    cfg.federation.devices = 8;
    cfg.scenario = Some(fedasync::scenario::ScenarioConfig {
        name: "dup_heavy".into(),
        faults: fedasync::scenario::FaultModel { drop_prob: 0.0, duplicate_prob: 0.4 },
        ..Default::default()
    });
    cfg.validate().unwrap();
    let p = QuadraticProblem::new(8, 4, 0.5, 2.0, 2.0, 0.0, 5, 1);
    let data = FederatedData { train: dummy_dataset(), test: dummy_dataset() };
    let mut fleet = dummy_fleet(8, 2);
    let log = run_fedasync(
        &p,
        &cfg,
        &data,
        &mut fleet,
        3,
        StalenessSource::Sampled { max: cfg.staleness.max },
    )
    .expect("duplication-heavy sampled run");
    assert!(log.rows.last().unwrap().test_loss.is_finite());
    // Every offer (originals + duplicate copies) landed in the histogram.
    assert!(log.staleness_hist.total() >= 100);
}

#[test]
fn emergent_vs_sampled_staleness_same_ballpark() {
    // DESIGN.md claims the paper's sampled-staleness protocol is a faithful
    // stand-in for emergent asynchrony; both must learn comparably.
    let Some(rt) = runtime() else { return };
    let cfg = short_cfg(Algo::FedAsync);
    let sampled = runner::run(&rt, &cfg).unwrap();
    let emergent = runner::run_once_emergent(&rt, &cfg, 0, 8).unwrap();
    let a_s = sampled.rows.last().unwrap().test_acc;
    let a_e = emergent.rows.last().unwrap().test_acc;
    assert!((a_s - a_e).abs() < 0.2, "sampled={a_s} emergent={a_e}");
}
