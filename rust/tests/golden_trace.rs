//! Golden-trace regression: a fixed-seed `run_sampled` run on a tiny
//! config pins its recorder rows against a checked-in JSON fixture, so
//! updater/metrics refactors that change numerics are caught loudly
//! instead of silently.
//!
//! The trainer is chosen so every pinned number is *exactly*
//! representable: it always returns the all-ones vector, and with
//! α = 0.5, staleness ≡ 1, no decay and no drops the global model after
//! `t` epochs is `1 − 2^{−t}` per element — dyadic rationals that f32/f64
//! arithmetic reproduces bit-exactly (for t ≤ 23).  Any change to the mix
//! formula's semantics, the α pipeline, the eval grid, or the CSV-facing
//! accounting (gradients/comms/clients/staleness windows) shifts these
//! rows and fails the comparison.
//!
//! Regenerate the fixture (after an *intentional* numerics change) with:
//!
//! ```bash
//! FEDASYNC_BLESS=1 cargo test --test golden_trace
//! ```

use std::path::PathBuf;

use fedasync::analysis::quadratic::{dummy_dataset, dummy_fleet};
use fedasync::config::{ExperimentConfig, LocalUpdate, StalenessFn};
use fedasync::coordinator::virtual_mode::{run_fedasync, StalenessSource};
use fedasync::coordinator::Trainer;
use fedasync::federated::data::{Dataset, FederatedData};
use fedasync::federated::device::SimDevice;
use fedasync::federated::metrics::MetricsLog;
use fedasync::runtime::{EvalMetrics, ParamVec, RuntimeError};
use fedasync::util::json::Json;

/// Always trains to the all-ones vector with loss 2.0; evaluation reports
/// mean(params) as loss (so the golden trajectory is closed-form).
struct ConstTrainer;

impl Trainer for ConstTrainer {
    fn param_count(&self) -> usize {
        4
    }
    fn init_params(&self, _seed_idx: usize) -> Result<ParamVec, RuntimeError> {
        Ok(vec![0.0; 4])
    }
    fn local_train(
        &self,
        _params: &[f32],
        _anchor: Option<&[f32]>,
        _device: &mut SimDevice,
        _data: &Dataset,
        _gamma: f32,
        _rho: f32,
        scratch: &mut fedasync::coordinator::TaskScratch,
    ) -> Result<(ParamVec, f32), RuntimeError> {
        let mut x = scratch.acquire(4);
        x.resize(4, 1.0);
        Ok((x, 2.0))
    }
    fn evaluate(&self, params: &[f32], _test: &Dataset) -> Result<EvalMetrics, RuntimeError> {
        let mean = params.iter().map(|&x| x as f64).sum::<f64>() / params.len() as f64;
        Ok(EvalMetrics { loss: mean, accuracy: 1.0 - mean, samples: params.len() })
    }
    fn local_iters(&self) -> usize {
        5
    }
}

fn golden_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "golden".into();
    cfg.seed = 9;
    cfg.epochs = 12;
    cfg.eval_every = 4;
    cfg.alpha = 0.5;
    cfg.alpha_decay = 1.0;
    cfg.alpha_decay_at = usize::MAX;
    cfg.local_update = LocalUpdate::Sgd;
    cfg.staleness.max = 1;
    cfg.staleness.func = StalenessFn::Constant;
    cfg.staleness.drop_above = None;
    cfg.federation.devices = 10;
    cfg
}

fn run_golden() -> MetricsLog {
    let cfg = golden_cfg();
    let data = FederatedData { train: dummy_dataset(), test: dummy_dataset() };
    let mut fleet = dummy_fleet(10, 2);
    run_fedasync(
        &ConstTrainer,
        &cfg,
        &data,
        &mut fleet,
        cfg.seed,
        StalenessSource::Sampled { max: cfg.staleness.max },
    )
    .expect("golden run")
}

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/golden_sampled.json")
}

/// Serialize rows with shortest-roundtrip float formatting (bless mode).
fn rows_to_json(log: &MetricsLog) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"label\": \"{}\",\n", log.label));
    out.push_str("  \"rows\": [\n");
    for (i, r) in log.rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"epoch\": {}, \"gradients\": {}, \"comms\": {}, \"sim_time\": {:?}, \
             \"train_loss\": {:?}, \"test_loss\": {:?}, \"test_acc\": {:?}, \
             \"alpha_eff\": {:?}, \"staleness\": {:?}, \"clients\": {}, \
             \"applied\": {}, \"buffered\": {}}}{}\n",
            r.epoch,
            r.gradients,
            r.comms,
            r.sim_time,
            r.train_loss,
            r.test_loss,
            r.test_acc,
            r.alpha_eff,
            r.staleness,
            r.clients,
            r.applied,
            r.buffered,
            if i + 1 == log.rows.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

#[test]
fn golden_trace_matches_fixture() {
    let log = run_golden();
    let path = fixture_path();
    if std::env::var("FEDASYNC_BLESS").is_ok() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, rows_to_json(&log)).unwrap();
        eprintln!("blessed golden fixture at {path:?}");
        return;
    }
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!("missing golden fixture {path:?} ({e}); run FEDASYNC_BLESS=1 to regenerate")
    });
    let want = Json::parse(&text).expect("fixture parses");
    assert_eq!(want.get("label").as_str(), Some(log.label.as_str()), "label drifted");
    let want_rows = want.get("rows").as_arr().expect("rows array");
    assert_eq!(
        want_rows.len(),
        log.rows.len(),
        "row count drifted: the eval grid changed"
    );
    for (i, (w, got)) in want_rows.iter().zip(&log.rows).enumerate() {
        let int = |key: &str| w.get(key).as_i64().unwrap_or_else(|| panic!("row {i}: {key}"));
        let num = |key: &str| w.get(key).as_f64().unwrap_or_else(|| panic!("row {i}: {key}"));
        assert_eq!(got.epoch as i64, int("epoch"), "row {i}: epoch");
        assert_eq!(got.gradients as i64, int("gradients"), "row {i}: gradients");
        assert_eq!(got.comms as i64, int("comms"), "row {i}: comms");
        assert_eq!(got.clients as i64, int("clients"), "row {i}: clients");
        // applied/buffered postdate the fixture format; compare when the
        // fixture carries them (a pre-aggregator fixture stays valid —
        // that absence is itself the byte-identity proof for the columns
        // that existed before the aggregation layer).
        for (key, have) in [("applied", got.applied), ("buffered", got.buffered)] {
            if let Some(want) = w.get(key).as_i64() {
                assert_eq!(have as i64, want, "row {i}: {key}");
            }
        }
        for (key, have) in [
            ("sim_time", got.sim_time),
            ("train_loss", got.train_loss),
            ("test_loss", got.test_loss),
            ("test_acc", got.test_acc),
            ("alpha_eff", got.alpha_eff),
            ("staleness", got.staleness),
        ] {
            let wantv = num(key);
            assert!(
                (have - wantv).abs() <= 1e-12,
                "row {i}: {key} drifted: fixture {wantv} vs run {have}"
            );
        }
    }
}

#[test]
fn golden_trace_is_deterministic() {
    let a = run_golden();
    let b = run_golden();
    assert_eq!(a.rows, b.rows, "same seed must reproduce identical rows");
}

#[test]
fn golden_hist_pins_staleness_accounting() {
    // Every one of the 12 offered updates has staleness exactly 1.
    let log = run_golden();
    assert_eq!(log.staleness_hist.total(), 12);
    assert_eq!(log.staleness_hist.support(), vec![1]);
    assert!((log.staleness_hist.mean() - 1.0).abs() < 1e-12);
}

#[test]
fn golden_default_aggregator_is_fedasync_applying_every_update() {
    // The default aggregator must be FedAsync: every offered update is
    // applied immediately (applied tracks the epoch counter) and nothing
    // is ever staged — the aggregation layer is invisible by default.
    let log = run_golden();
    let last = log.rows.last().expect("rows");
    assert_eq!(last.applied, 12, "default aggregator must apply all 12 updates");
    assert!(
        log.rows.iter().all(|r| r.buffered == 0),
        "default aggregator must never buffer"
    );
    assert!(
        log.rows.iter().all(|r| r.applied == r.epoch as u64),
        "FedAsync applied-count must track the epoch counter"
    );
}
