//! Zero-allocation regression for the compute plane.
//!
//! The sequential driver's steady state — local_train (fused SoA kernel
//! over `TaskScratch` buffers) → delivery draw → offer (pooled mix +
//! Arc-reusing history push) → off-grid record → buffer recycle — must
//! perform **zero heap allocations per task**.  A counting global
//! allocator measures a probe-bracketed window of steady-state tasks
//! inside a real engine run; any new allocation on the hot path (a
//! stray `to_vec`, a fresh mix buffer, a non-recycled history push)
//! fails this test.
//!
//! The probe machinery and the measured run live in
//! `tests/support/alloc_probe.rs`, shared with `bench_compute` so the
//! pinned invariant and the published `allocs_per_task_steady_state`
//! bench field always measure the same workload.
//!
//! This file is its own test binary, and the measured runs serialize on
//! a lock, so no concurrent test can allocate inside a measurement
//! window.
//!
//! Two windows are pinned:
//! * sequential driver on a uniform fleet — exactly **zero** heap
//!   allocations per task (the original compute-plane pin);
//! * event driver on a `million_fleet` scenario slice with a metrics
//!   row *streamed every epoch* — a small O(1)-per-task ceiling
//!   (timer-wheel slots size lazily), with the row path required to
//!   emit through the sink rather than buffer.

use std::sync::Mutex;

#[path = "support/alloc_probe.rs"]
mod alloc_probe;

#[global_allocator]
static COUNTER: alloc_probe::CountingAlloc = alloc_probe::CountingAlloc;

/// Serializes the measured engine runs across test threads.
static SERIAL: Mutex<()> = Mutex::new(());

/// Ceiling on event-driver steady-state allocations, per task cycle.
///
/// The path is not zero-alloc by design — timer-wheel slots size
/// themselves lazily and the fallback idle scan may grow its buffer —
/// but each source is O(1) amortized per task.  Anything O(rows) or
/// O(fleet) per task (a buffered metrics row, a per-assign scan
/// allocation) blows well past this bound.
const EVENT_ALLOCS_PER_TASK_CEILING: u64 = 4;

#[test]
fn sequential_driver_steady_state_allocates_zero_per_task() {
    let _guard = SERIAL.lock().unwrap();
    let report = alloc_probe::run_steady_state();
    assert_eq!(report.final_epoch, 600, "run must complete");
    assert_eq!(
        report.allocs_in_window,
        0,
        "steady state allocated {} times over {} tasks (want 0/task)",
        report.allocs_in_window,
        report.tasks
    );
}

#[test]
fn event_driver_steady_state_allocates_o1_per_task_while_streaming() {
    let _guard = SERIAL.lock().unwrap();
    let report = alloc_probe::run_event_steady_state();
    assert_eq!(report.final_epoch, 520, "run must complete");
    assert!(!report.rows_buffered, "streaming log buffered rows in memory");
    assert!(
        report.rows_emitted >= report.tasks,
        "only {} rows streamed over {}+ task cycles — eval grid not inside the window",
        report.rows_emitted,
        report.tasks
    );
    let ceiling = EVENT_ALLOCS_PER_TASK_CEILING * report.tasks;
    assert!(
        report.allocs_in_window <= ceiling,
        "event steady state allocated {} times over {} tasks (ceiling {})",
        report.allocs_in_window,
        report.tasks,
        ceiling
    );
}
