//! Zero-allocation regression for the compute plane.
//!
//! The sequential driver's steady state — local_train (fused SoA kernel
//! over `TaskScratch` buffers) → delivery draw → offer (pooled mix +
//! Arc-reusing history push) → off-grid record → buffer recycle — must
//! perform **zero heap allocations per task**.  A counting global
//! allocator measures a probe-bracketed window of steady-state tasks
//! inside a real engine run; any new allocation on the hot path (a
//! stray `to_vec`, a fresh mix buffer, a non-recycled history push)
//! fails this test.
//!
//! The probe machinery and the measured run live in
//! `tests/support/alloc_probe.rs`, shared with `bench_compute` so the
//! pinned invariant and the published `allocs_per_task_steady_state`
//! bench field always measure the same workload.
//!
//! This file is its own test binary with a single `#[test]` so no
//! concurrent test can allocate inside the measurement window.

#[path = "support/alloc_probe.rs"]
mod alloc_probe;

#[global_allocator]
static COUNTER: alloc_probe::CountingAlloc = alloc_probe::CountingAlloc;

#[test]
fn sequential_driver_steady_state_allocates_zero_per_task() {
    let report = alloc_probe::run_steady_state();
    assert_eq!(report.final_epoch, 600, "run must complete");
    assert_eq!(
        report.allocs_in_window,
        0,
        "steady state allocated {} times over {} tasks (want 0/task)",
        report.allocs_in_window,
        report.tasks
    );
}
