//! Shared steady-state allocation probe for the alloc-regression test
//! and `bench_compute` — one definition of the counting allocator, the
//! window-bracketing probe behavior, and the sequential engine run they
//! both measure, so the tier-1 "0 allocs/task" pin and the published
//! `allocs_per_task_steady_state` bench field can never measure two
//! different workloads.
//!
//! Not a test file itself: it lives in a subdirectory (cargo only
//! auto-builds `tests/*.rs`), and each consumer includes it via
//! `#[path]` and installs [`CountingAlloc`] as its own
//! `#[global_allocator]` (the attribute is per-binary).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use fedasync::analysis::quadratic::{dummy_dataset, dummy_fleet, QuadraticProblem};
use fedasync::config::{ExperimentConfig, LocalUpdate, StalenessFn};
use fedasync::coordinator::core::UpdaterCore;
use fedasync::coordinator::engine::{Engine, EventDriver, SequentialDriver};
use fedasync::coordinator::Trainer;
use fedasync::federated::data::FederatedData;
use fedasync::scenario::{presets, ClientBehavior, Delivery, ScenarioBehavior, UniformBehavior};
use fedasync::util::rng::Rng;

/// System allocator wrapper that counts every allocation entry point
/// (dealloc is free to happen — steady state may *shrink*, never grow).
pub struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

fn allocs_now() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Tasks run before the window opens (steady footprint reached: scratch
/// buffers, the history ring, the staleness histogram, the buffer pool).
const WARMUP_TASKS: u64 = 200;
/// Task cycles measured inside the window.
const MEASURE_TASKS: u64 = 200;

/// Wrapper population that snapshots the allocation counter at the
/// window edges; `delivery` is the engine's once-per-arrival hook, so
/// bracketing deliveries `N` and `N + M` measures `M` complete task
/// cycles (train → deliver → offer → off-grid record → recycle).
/// Generic over the wrapped behavior: the sequential pin runs it over
/// [`UniformBehavior`], the event-driver pin over the `million_fleet`
/// [`ScenarioBehavior`].
struct ProbeBehavior<B: ClientBehavior> {
    inner: B,
    deliveries: AtomicU64,
    window_start: AtomicU64,
    window_end: AtomicU64,
}

impl<B: ClientBehavior> ClientBehavior for ProbeBehavior<B> {
    fn label(&self) -> String {
        self.inner.label()
    }

    fn is_present(&self, device: usize, progress: f64) -> bool {
        self.inner.is_present(device, progress)
    }

    fn present_count(&self, progress: f64) -> usize {
        self.inner.present_count(progress)
    }

    fn slowdown(&self, device: usize, progress: f64) -> f64 {
        self.inner.slowdown(device, progress)
    }

    fn link_latency(&self, device: usize, rng: &mut Rng) -> f64 {
        self.inner.link_latency(device, rng)
    }

    fn sample_staleness(&self, device: usize, progress: f64, max: u64, rng: &mut Rng) -> u64 {
        self.inner.sample_staleness(device, progress, max, rng)
    }

    fn delivery(&self, device: usize, progress: f64, rng: &mut Rng) -> Delivery {
        let k = self.deliveries.fetch_add(1, Ordering::Relaxed);
        if k == WARMUP_TASKS {
            self.window_start.store(allocs_now(), Ordering::Relaxed);
        } else if k == WARMUP_TASKS + MEASURE_TASKS {
            self.window_end.store(allocs_now(), Ordering::Relaxed);
        }
        self.inner.delivery(device, progress, rng)
    }
}

/// What [`run_steady_state`] measured.
pub struct SteadyStateReport {
    /// Heap allocations observed inside the probe window.
    pub allocs_in_window: u64,
    /// Task cycles the window spans.
    pub tasks: u64,
    /// Final epoch the run reached (sanity: the run completed).
    pub final_epoch: usize,
}

/// One sequential-driver engine run on the closed-form quadratic with
/// the eval grid kept clear of the probe window; panics if the window
/// never closed.
pub fn run_steady_state() -> SteadyStateReport {
    const DEVICES: usize = 8;
    let mut cfg = ExperimentConfig::default();
    cfg.name = "alloc_probe".into();
    cfg.epochs = 600;
    cfg.eval_every = 600; // rows only at t = 0 and t = 600: window is row-free
    cfg.repeats = 1;
    cfg.seed = 1;
    cfg.gamma = 0.05;
    cfg.alpha = 0.6;
    cfg.alpha_decay = 1.0;
    cfg.alpha_decay_at = usize::MAX;
    cfg.local_update = LocalUpdate::Sgd;
    cfg.staleness.max = 4;
    cfg.staleness.func = StalenessFn::Poly { a: 0.5 };
    cfg.staleness.drop_above = None;
    cfg.federation.devices = DEVICES;

    // Gradient noise on, so the fill_gaussian path is inside the window.
    let problem = QuadraticProblem::new(DEVICES, 16, 0.5, 2.0, 2.0, 0.05, 5, 1);
    let data = FederatedData { train: dummy_dataset(), test: dummy_dataset() };
    let mut fleet = dummy_fleet(DEVICES, 2);
    let probe = ProbeBehavior {
        inner: UniformBehavior::new(DEVICES),
        deliveries: AtomicU64::new(0),
        window_start: AtomicU64::new(0),
        window_end: AtomicU64::new(0),
    };

    let core = UpdaterCore::new(
        &cfg,
        Trainer::init_params(&problem, 0).expect("init"),
        cfg.staleness.max as usize + 1,
        &data.test,
        None,
    );
    let driver =
        SequentialDriver::new(&cfg, &data, &mut fleet, &probe, cfg.seed, cfg.staleness.max);
    let log = Engine::new(&problem, &cfg, &probe).run(core, driver).expect("steady-state run");

    let start = probe.window_start.load(Ordering::Relaxed);
    let end = probe.window_end.load(Ordering::Relaxed);
    assert!(start > 0 && end >= start, "probe window never closed");
    SteadyStateReport {
        allocs_in_window: end - start,
        tasks: MEASURE_TASKS,
        final_epoch: log.rows.last().expect("rows").epoch,
    }
}

/// What [`run_event_steady_state`] measured.
// Only the alloc-regression binary calls the event-driver probe;
// `bench_compute` includes this file too, so the items are allowed to
// be unused per-binary.
#[allow(dead_code)]
pub struct EventSteadyStateReport {
    /// Heap allocations observed inside the probe window.
    pub allocs_in_window: u64,
    /// Task cycles the window spans.
    pub tasks: u64,
    /// Rows the streaming log emitted over the whole run.
    pub rows_emitted: u64,
    /// Whether any row was buffered in memory (must stay `false`).
    pub rows_buffered: bool,
    /// Final epoch the run reached (sanity: the run completed).
    pub final_epoch: usize,
}

/// One event-driver engine run over a `million_fleet` scenario slice
/// with metrics streamed to a sink and a row recorded **every** epoch,
/// so the probe window brackets the full scale plane: timer-wheel
/// scheduling, SoA behavior queries, and streaming row emission.
///
/// Unlike the sequential pin this is not a zero-alloc path — timer-wheel
/// slots lazily size themselves and the fallback idle scan may grow its
/// buffer — but every such source is O(1) amortized per task, and rows
/// must leave through the sink rather than accumulate: the caller
/// asserts a small per-task allocation bound and an empty `rows` vec.
#[allow(dead_code)]
pub fn run_event_steady_state() -> EventSteadyStateReport {
    const DEVICES: usize = 2048;
    const INFLIGHT: usize = 64;
    let mut cfg = ExperimentConfig::default();
    cfg.name = "alloc_probe_event".into();
    cfg.epochs = 520; // window closes at delivery 400; ~1% fault slack
    cfg.eval_every = 1; // a streamed row lands inside every task cycle
    cfg.repeats = 1;
    cfg.seed = 7;
    cfg.gamma = 0.05;
    cfg.alpha = 0.6;
    cfg.alpha_decay = 1.0;
    cfg.alpha_decay_at = usize::MAX;
    cfg.local_update = LocalUpdate::Sgd;
    cfg.staleness.max = 16;
    cfg.staleness.func = StalenessFn::Poly { a: 0.5 };
    cfg.staleness.drop_above = None;
    cfg.federation.devices = DEVICES;

    let sc = presets::named("million_fleet").expect("million_fleet preset");
    let problem = QuadraticProblem::new(DEVICES, 16, 0.5, 2.0, 2.0, 0.05, 5, 1);
    let data = FederatedData { train: dummy_dataset(), test: dummy_dataset() };
    let mut fleet = dummy_fleet(DEVICES, 2);
    let probe = ProbeBehavior {
        inner: ScenarioBehavior::new(&sc, DEVICES, cfg.seed),
        deliveries: AtomicU64::new(0),
        window_start: AtomicU64::new(0),
        window_end: AtomicU64::new(0),
    };

    let mut core = UpdaterCore::new(
        &cfg,
        Trainer::init_params(&problem, 0).expect("init"),
        cfg.staleness.max as usize + 1,
        &data.test,
        None,
    );
    core.rec
        .log
        .stream_rows_to(Box::new(std::io::sink()))
        .expect("attach streaming sink");
    let driver = EventDriver::new(&cfg, &data, &mut fleet, &probe, cfg.seed, INFLIGHT);
    let log =
        Engine::new(&problem, &cfg, &probe).run(core, driver).expect("event steady-state run");

    let start = probe.window_start.load(Ordering::Relaxed);
    let end = probe.window_end.load(Ordering::Relaxed);
    assert!(start > 0 && end >= start, "probe window never closed");
    EventSteadyStateReport {
        allocs_in_window: end - start,
        tasks: MEASURE_TASKS,
        rows_emitted: log.rows_recorded(),
        rows_buffered: !log.rows.is_empty(),
        final_epoch: log.last().expect("final row").epoch,
    }
}
