//! Property-based tests over coordinator/substrate invariants.
//!
//! Uses the in-tree harness (`util::prop`) — randomized, seeded, replayable
//! cases; failures print the seed for `check_one`.  These guard the
//! invariants DESIGN.md calls out: mixing stays on the segment, staleness
//! adaptation is monotone and bounded, partitions are exact covers, the
//! model-store ring honors its retention contract, the event queue is a
//! total order, and update accounting never drifts.

use fedasync::config::{Partition, StalenessConfig, StalenessFn};
use fedasync::coordinator::model_store::ModelStore;
use fedasync::coordinator::staleness::{AlphaController, AlphaDecision};
use fedasync::coordinator::updater::{
    mix_inplace, mix_inplace_sharded, mix_into, mix_into_buf, SHARD_MIN_LEN,
};
use fedasync::federated::network::{EventQueue, HeapEventQueue};
use fedasync::federated::{data, partition};
use fedasync::prop_ensure;
use fedasync::util::kernels;
use fedasync::util::prop::{check, Gen};

fn random_staleness_fn(g: &mut Gen) -> StalenessFn {
    match g.index(5) {
        0 => StalenessFn::Constant,
        1 => StalenessFn::Linear { a: g.f64_in(0.0, 20.0) },
        2 => StalenessFn::Poly { a: g.f64_in(0.0, 4.0) },
        3 => StalenessFn::Exp { a: g.f64_in(0.0, 4.0) },
        _ => StalenessFn::Hinge { a: g.f64_in(0.1, 20.0), b: g.f64_in(0.0, 16.0) },
    }
}

#[test]
fn prop_staleness_functions_bounded_and_monotone() {
    check("staleness-bounded-monotone", 200, |g| {
        let f = random_staleness_fn(g);
        let mut prev = f64::INFINITY;
        for s in 0..200u64 {
            let v = f.eval(s);
            // v may underflow to exactly 0 for extreme staleness — the
            // paper's "α = 0 ⇒ effectively dropped" case.
            prop_ensure!((0.0..=1.0).contains(&v), "{f:?} s={s} v={v}");
            prop_ensure!(v <= prev + 1e-12, "{f:?} not non-increasing at s={s}");
            prev = v;
        }
        prop_ensure!((f.eval(0) - 1.0).abs() < 1e-12, "{f:?} s(0) != 1");
        Ok(())
    });
}

#[test]
fn prop_alpha_controller_in_unit_interval_and_drop_consistent() {
    check("alpha-controller", 200, |g| {
        let alpha = g.f64_in(0.01, 1.0);
        let drop_above = g.bool().then(|| g.index(32) as u64);
        let ctl = AlphaController::new(
            alpha,
            g.f64_in(0.1, 1.0),
            g.index(1000),
            &StalenessConfig { max: 32, func: random_staleness_fn(g), drop_above },
        );
        for s in 0..32u64 {
            match ctl.decide(g.index(2000), s) {
                AlphaDecision::Mix(a) => {
                    prop_ensure!(a > 0.0 && a <= 1.0, "a={a}");
                    if let Some(cut) = drop_above {
                        prop_ensure!(s <= cut, "should have dropped s={s} cut={cut}");
                    }
                }
                AlphaDecision::Drop => {
                    let cut = drop_above.ok_or("drop without policy")?;
                    prop_ensure!(s > cut, "dropped fresh update s={s} cut={cut}");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_alpha_controller_monotone_in_staleness() {
    // s(τ) non-increasing in τ for the paper's adaptive families ⇒ the
    // controller's Mix(α) must be non-increasing in staleness too.
    check("alpha-monotone", 200, |g| {
        let func = if g.bool() {
            StalenessFn::Poly { a: g.f64_in(0.0, 4.0) }
        } else {
            StalenessFn::Hinge { a: g.f64_in(0.1, 20.0), b: g.f64_in(0.0, 16.0) }
        };
        let ctl = AlphaController::new(
            g.f64_in(0.01, 1.0),
            g.f64_in(0.1, 1.0),
            g.index(1000),
            &StalenessConfig { max: 64, func, drop_above: None },
        );
        let t = g.index(2000);
        let mut prev = f64::INFINITY;
        for s in 0..64u64 {
            match ctl.decide(t, s) {
                AlphaDecision::Mix(a) => {
                    prop_ensure!(a > 0.0 && a <= 1.0, "{func:?} t={t} s={s} a={a}");
                    prop_ensure!(
                        a <= prev + 1e-12,
                        "{func:?} t={t}: alpha rose from {prev} to {a} at s={s}"
                    );
                    prev = a;
                }
                AlphaDecision::Drop => return Err("drop without a drop policy".into()),
            }
        }
        Ok(())
    });
}

#[test]
fn prop_alpha_controller_drop_iff_above_cutoff() {
    check("alpha-drop-iff", 200, |g| {
        let cut = g.index(32) as u64;
        let ctl = AlphaController::new(
            g.f64_in(0.01, 1.0),
            g.f64_in(0.1, 1.0),
            g.index(1000),
            &StalenessConfig { max: 64, func: random_staleness_fn(g), drop_above: Some(cut) },
        );
        let t = g.index(2000);
        for s in 0..64u64 {
            let dropped = matches!(ctl.decide(t, s), AlphaDecision::Drop);
            prop_ensure!(
                dropped == (s > cut),
                "cut={cut} s={s}: dropped={dropped}, want {}",
                s > cut
            );
        }
        Ok(())
    });
}

#[test]
fn prop_alpha_decay_steps_exactly_at_decay_at() {
    // The ×decay step applies exactly at `decay_at`: base α one epoch
    // before, base·decay from then on — and `decide` at staleness 0
    // (s(0) = 1 for every family) exposes the base directly.
    check("alpha-decay-step", 200, |g| {
        let base = g.f64_in(0.01, 1.0);
        let decay = g.f64_in(0.1, 1.0);
        let at = 1 + g.index(999);
        let ctl = AlphaController::new(
            base,
            decay,
            at,
            &StalenessConfig { max: 16, func: random_staleness_fn(g), drop_above: None },
        );
        prop_ensure!((ctl.base_at(0) - base).abs() < 1e-12, "t=0 base");
        prop_ensure!((ctl.base_at(at - 1) - base).abs() < 1e-12, "pre-decay base");
        prop_ensure!(
            (ctl.base_at(at) - base * decay).abs() < 1e-12,
            "decay not applied at t={at}"
        );
        prop_ensure!(
            (ctl.base_at(at + g.index(1000)) - base * decay).abs() < 1e-12,
            "decay not sticky after t={at}"
        );
        match ctl.decide(at, 0) {
            AlphaDecision::Mix(a) => {
                let want = (base * decay).clamp(0.0, 1.0);
                prop_ensure!((a - want).abs() < 1e-12, "decide({at}, 0) = {a}, want {want}");
            }
            AlphaDecision::Drop => return Err("drop without a drop policy".into()),
        }
        Ok(())
    });
}

#[test]
fn prop_mix_stays_on_segment_and_interpolates() {
    check("mix-segment", 300, |g| {
        let n = g.size(1, 4096);
        let x0 = g.vec_f32(n, 2.0);
        let y = g.vec_f32(n, 2.0);
        let alpha = g.f64_in(0.0, 1.0) as f32;
        let mut x = x0.clone();
        mix_inplace(&mut x, &y, alpha);
        for i in 0..n {
            let (lo, hi) = if x0[i] <= y[i] { (x0[i], y[i]) } else { (y[i], x0[i]) };
            prop_ensure!(
                x[i] >= lo - 1e-4 && x[i] <= hi + 1e-4,
                "i={i} out of segment: {} not in [{lo}, {hi}]",
                x[i]
            );
            let want = (1.0 - alpha) * x0[i] + alpha * y[i];
            prop_ensure!((x[i] - want).abs() < 1e-4, "i={i}: {} vs {want}", x[i]);
        }
        Ok(())
    });
}

#[test]
fn prop_mix_family_agrees_bitwise() {
    // `mix_into`, `mix_into_buf`, `mix_inplace`, and `mix_inplace_sharded`
    // are four spellings of the same single line of math; any divergence —
    // a reordered reduction, an FMA sneaking into one path — would split
    // the execution modes' trajectories.  They must agree *bitwise* for
    // arbitrary lengths, alphas, and shard counts, with lengths straddling
    // the `SHARD_MIN_LEN` boundary on both sides.  The elementwise op is
    // reassociation-free, so the `util::kernels` scalar reference and the
    // LANES-chunked fast path join the bitwise family too — whichever one
    // the `fast-kernels` feature dispatched (both build modes run this).
    check("mix-family-bitwise", 60, |g| {
        let n = match g.index(4) {
            0 => g.size(1, 2048),
            // Within a few elements of the sharding threshold.
            1 => SHARD_MIN_LEN - 32 + g.size(0, 64),
            // Big enough to genuinely shard on multi-core machines.
            2 => 2 * SHARD_MIN_LEN + g.size(0, 1024),
            // Guaranteed odd and sharded: the last shard chunk (run
            // inline on the calling thread) ends in a scalar remainder.
            _ => 2 * SHARD_MIN_LEN + 1 + 2 * g.size(0, 512),
        };
        let alpha = g.f64_in(0.0, 1.0) as f32;
        let x = g.vec_f32(n, 2.0);
        let y = g.vec_f32(n, 2.0);
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();
        let reference = mix_into(&x, &y, alpha);

        let mut inplace = x.clone();
        mix_inplace(&mut inplace, &y, alpha);
        prop_ensure!(
            bits(&inplace) == bits(&reference),
            "mix_inplace != mix_into at n={n} alpha={alpha}"
        );

        // A dirty recycled buffer must not leak into the result.
        let mut buf = vec![9.0f32; g.size(0, 8)];
        mix_into_buf(&x, &y, alpha, &mut buf);
        prop_ensure!(
            bits(&buf) == bits(&reference),
            "mix_into_buf != mix_into at n={n} alpha={alpha}"
        );

        for shards in [1usize, 2, 3, 5, 8, 64] {
            let mut sharded = x.clone();
            mix_inplace_sharded(&mut sharded, &y, alpha, shards);
            prop_ensure!(
                bits(&sharded) == bits(&reference),
                "mix_inplace_sharded(shards={shards}) != mix_into at n={n} alpha={alpha}"
            );
        }

        // Both explicit kernel variants, regardless of which one the
        // feature selected for the dispatched family above.
        let mut scalar = x.clone();
        kernels::mix_scalar(&mut scalar, &y, alpha);
        prop_ensure!(
            bits(&scalar) == bits(&reference),
            "kernels::mix_scalar != mix_into at n={n} alpha={alpha}"
        );
        let mut chunked = x.clone();
        kernels::mix_chunked(&mut chunked, &y, alpha);
        prop_ensure!(
            bits(&chunked) == bits(&reference),
            "kernels::mix_chunked != mix_into at n={n} alpha={alpha}"
        );
        let mut into_chunked = vec![5.0f32; g.size(0, 8)];
        kernels::mix_into_chunked(&x, &y, alpha, &mut into_chunked);
        prop_ensure!(
            bits(&into_chunked) == bits(&reference),
            "kernels::mix_into_chunked != mix_into at n={n} alpha={alpha}"
        );
        Ok(())
    });
}

#[test]
fn prop_mix_idempotent_when_equal() {
    check("mix-idempotent", 100, |g| {
        let n = g.size(1, 1024);
        let x0 = g.vec_f32(n, 3.0);
        let mut x = x0.clone();
        let alpha = g.f64_in(0.0, 1.0) as f32;
        mix_inplace(&mut x, &x0, alpha);
        for i in 0..n {
            prop_ensure!((x[i] - x0[i]).abs() < 1e-5, "i={i}");
        }
        Ok(())
    });
}

#[test]
fn prop_model_store_retention_contract() {
    check("model-store", 150, |g| {
        let cap = g.size(1, 40);
        let pushes = g.size(0, 100);
        let mut store = ModelStore::new(vec![0.0f32], cap);
        for v in 1..=pushes as u64 {
            store.push(vec![v as f32]);
        }
        let current = store.current_version();
        prop_ensure!(current == pushes as u64, "version {current} != {pushes}");
        // Everything within the window resolves to the right payload;
        // everything outside is None.
        for v in 0..=current {
            let age = (current - v) as usize;
            match store.get(v) {
                Some(p) => {
                    prop_ensure!(age < cap, "v={v} should be evicted (cap={cap})");
                    prop_ensure!(p[0] == v as f32, "wrong payload at v={v}");
                }
                None => prop_ensure!(age >= cap, "v={v} should be retained (cap={cap})"),
            }
        }
        prop_ensure!(store.get(current + 1).is_none(), "future version resolved");
        Ok(())
    });
}

#[test]
fn prop_partitions_are_exact_covers() {
    check("partition-cover", 40, |g| {
        let devices = g.size(1, 30);
        let spd = g.size(1, 4);
        let cfg = fedasync::config::FederationConfig {
            devices,
            samples_per_device: g.size(2, 40),
            test_samples: 8,
            partition: Partition::Iid,
            dataset: fedasync::config::Dataset::Features,
            label_noise: 0.0,
            class_sep: 1.0,
        };
        let d = data::generate(&cfg, g.rng.next_u64()).train;
        for strat in [
            Partition::Iid,
            Partition::Shards { shards_per_device: spd },
            Partition::Dirichlet { beta: g.f64_in(0.05, 10.0) },
        ] {
            let p = partition::partition(&d, devices, strat, g.rng.next_u64());
            prop_ensure!(p.is_exact_cover(d.len()), "{strat:?} not an exact cover");
            prop_ensure!(
                p.assignment.len() == devices,
                "{strat:?} wrong device count"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_event_queue_total_order() {
    check("event-queue", 100, |g| {
        let n = g.size(0, 200);
        let mut q = EventQueue::new();
        for i in 0..n {
            q.schedule_at(g.f64_in(0.0, 100.0), i);
        }
        let mut prev_t = f64::NEG_INFINITY;
        let mut seen = vec![false; n];
        while let Some(ev) = q.pop() {
            prop_ensure!(ev.at >= prev_t, "time went backwards");
            prev_t = ev.at;
            prop_ensure!(!seen[ev.payload], "duplicate event {}", ev.payload);
            seen[ev.payload] = true;
        }
        prop_ensure!(seen.iter().all(|&s| s), "lost events");
        Ok(())
    });
}

#[test]
fn prop_event_queue_pops_in_time_then_seq_order() {
    // Regression companion to the non-finite-timestamp fix: under random
    // insertions (with coarse times forcing plenty of ties) the queue
    // must pop in strict (time, seq) lexicographic order — the seq
    // tie-break is what keeps same-instant events FIFO.
    check("event-queue-time-seq", 100, |g| {
        let n = g.size(0, 300);
        let mut q = EventQueue::new();
        for i in 0..n {
            let at = g.index(8) as f64;
            q.schedule_at(at, i);
        }
        let mut prev: Option<(f64, u64)> = None;
        let mut popped = 0usize;
        while let Some(ev) = q.pop() {
            if let Some((pt, ps)) = prev {
                prop_ensure!(
                    ev.at > pt || (ev.at == pt && ev.seq > ps),
                    "out of (time, seq) order: ({pt}, {ps}) then ({}, {})",
                    ev.at,
                    ev.seq
                );
            }
            prev = Some((ev.at, ev.seq));
            popped += 1;
        }
        prop_ensure!(popped == n, "lost events: {popped} of {n}");
        Ok(())
    });
}

#[test]
fn prop_scenario_behavior_invariants() {
    use fedasync::scenario::{
        ChurnPhase, ClientBehavior, FaultModel, ScenarioBehavior, ScenarioConfig, SpeedTier,
        StragglerBurst,
    };
    check("scenario-behavior", 60, |g| {
        let n = g.size(2, 60);
        let mut sc = ScenarioConfig { name: "prop".into(), ..ScenarioConfig::default() };
        if g.bool() {
            let k = g.size(1, 4);
            sc.tiers = (0..k)
                .map(|_| SpeedTier {
                    fraction: g.f64_in(0.05, 1.0),
                    speed: g.f64_in(0.05, 4.0),
                    latency_mu: g.f64_in(-4.0, 0.0),
                    latency_sigma: g.f64_in(0.0, 1.5),
                })
                .collect();
        }
        if g.bool() {
            let mut at = 0.0;
            sc.churn = (0..g.size(1, 4))
                .map(|_| {
                    at = g.f64_in(at, 1.0);
                    ChurnPhase { at, present: g.f64_in(0.05, 1.0) }
                })
                .collect();
        }
        if g.bool() {
            let from = g.f64_in(0.0, 0.9);
            sc.bursts = vec![StragglerBurst {
                from,
                until: g.f64_in(from + 0.01, 1.0).min(1.0).max(from + 1e-6),
                fraction: g.f64_in(0.05, 1.0),
                slowdown: g.f64_in(1.0, 32.0),
            }];
        }
        sc.faults = FaultModel {
            drop_prob: g.f64_in(0.0, 0.4),
            duplicate_prob: g.f64_in(0.0, 0.4),
        };
        sc.validate().map_err(|e| e.to_string())?;
        let b = ScenarioBehavior::new(&sc, n, g.rng.next_u64());
        let max = 1 + g.index(32) as u64;
        for progress in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let pc = b.present_count(progress);
            prop_ensure!(pc >= 1 && pc <= n, "present_count {pc} outside [1, {n}]");
            let actual = (0..n).filter(|&d| b.is_present(d, progress)).count();
            prop_ensure!(actual == pc, "present set {actual} != count {pc}");
            for d in 0..n {
                let s = b.slowdown(d, progress);
                prop_ensure!(s.is_finite() && s > 0.0, "slowdown {s}");
            }
            for _ in 0..20 {
                let d = g.index(n);
                let s = b.sample_staleness(d, progress, max, &mut g.rng);
                prop_ensure!((1..=max).contains(&s), "staleness {s} outside [1, {max}]");
                let lat = b.link_latency(d, &mut g.rng);
                prop_ensure!(lat > 0.0 && lat.is_finite(), "latency {lat}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rng_choose_k_uniformish() {
    // Every index should be chosen sometimes — no systematic exclusion.
    check("choose-k-coverage", 20, |g| {
        let n = g.size(2, 50);
        let k = g.size(1, n);
        let mut hit = vec![false; n];
        for _ in 0..400 {
            for idx in g.rng.choose_k(n, k) {
                hit[idx] = true;
            }
        }
        prop_ensure!(hit.iter().all(|&h| h), "n={n} k={k}: some index never chosen");
        Ok(())
    });
}

#[test]
fn prop_device_epoch_batch_labels_from_shard() {
    check("device-batch-labels", 30, |g| {
        let cfg = fedasync::config::FederationConfig {
            devices: 4,
            samples_per_device: g.size(3, 30),
            test_samples: 8,
            partition: Partition::Iid,
            dataset: fedasync::config::Dataset::Features,
            label_noise: 0.0,
            class_sep: 1.0,
        };
        let d = data::generate(&cfg, g.rng.next_u64()).train;
        let shard: Vec<usize> = (0..g.size(1, d.len())).collect();
        let mut dev = fedasync::federated::device::SimDevice::new(
            0,
            shard.clone(),
            1.0,
            fedasync::federated::device::AvailabilityModel::default(),
            fedasync::util::rng::Rng::seed_from(g.rng.next_u64()),
        );
        let h = g.size(1, 5);
        let b = g.size(1, 10);
        let eb = dev.next_epoch_batch(&d, h, b);
        prop_ensure!(eb.labels.len() == h * b, "wrong batch size");
        prop_ensure!(eb.images.len() == h * b * d.input_size, "wrong image size");
        let allowed: std::collections::BTreeSet<i32> =
            shard.iter().map(|&i| d.labels[i]).collect();
        for l in &eb.labels {
            prop_ensure!(allowed.contains(l), "label {l} not in shard");
        }
        Ok(())
    });
}

#[test]
fn prop_metrics_csv_roundtrip() {
    use fedasync::federated::metrics::{MetricsLog, MetricsRow};
    check("metrics-roundtrip", 50, |g| {
        let mut log = MetricsLog::new("series");
        let rows = g.size(0, 30);
        for i in 0..rows {
            log.push(MetricsRow {
                epoch: i * 10,
                gradients: g.rng.below(1_000_000),
                comms: g.rng.below(1_000_000),
                sim_time: g.f64_in(0.0, 1e4),
                train_loss: g.f64_in(0.0, 10.0),
                test_loss: g.f64_in(0.0, 10.0),
                test_acc: g.f64_in(0.0, 1.0),
                alpha_eff: g.f64_in(0.0, 1.0),
                staleness: g.f64_in(0.0, 32.0),
                clients: g.size(1, 500),
                applied: g.rng.below(1_000_000),
                buffered: g.rng.below(1_000_000),
            });
        }
        let back = MetricsLog::from_csv("series", &log.to_csv()).map_err(|e| e)?;
        prop_ensure!(back.rows.len() == log.rows.len(), "row count changed");
        for (a, b) in log.rows.iter().zip(&back.rows) {
            prop_ensure!(a.epoch == b.epoch, "epoch changed");
            prop_ensure!(a.gradients == b.gradients, "gradients changed");
            prop_ensure!((a.test_acc - b.test_acc).abs() < 1e-5, "acc drifted");
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_arbitrary_trees() {
    use fedasync::util::json::{Json, JsonObj};
    fn gen_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.index(4) } else { g.index(6) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.rng.below(1 << 40) as f64) - (1u64 << 39) as f64),
            3 => Json::Str(
                (0..g.size(0, 12))
                    .map(|_| char::from(32 + g.index(90) as u8))
                    .collect(),
            ),
            4 => Json::Arr((0..g.size(0, 5)).map(|_| gen_json(g, depth - 1)).collect()),
            _ => {
                let mut o = JsonObj::new();
                for i in 0..g.size(0, 5) {
                    o.insert(format!("k{i}"), gen_json(g, depth - 1));
                }
                Json::Obj(o)
            }
        }
    }
    check("json-roundtrip", 150, |g| {
        let v = gen_json(g, 3);
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            let back = Json::parse(&text).map_err(|e| e.to_string())?;
            prop_ensure!(back == v, "roundtrip mismatch: {text}");
        }
        Ok(())
    });
}

#[test]
fn shipped_scenario_configs_match_their_named_presets() {
    // The scenario_*.toml files spell out their keys for documentation
    // value, but each claims a preset's name — pin them byte-equal to
    // `scenario::presets::named` so tuning a preset can't silently fork
    // the shipped configs into a different population with the same name.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut checked = 0;
    for entry in std::fs::read_dir(&dir).expect("configs/ exists") {
        let path = entry.unwrap().path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if !name.starts_with("scenario_") || !name.ends_with(".toml") {
            continue;
        }
        let cfg = fedasync::config::ExperimentConfig::from_toml_file(&path)
            .unwrap_or_else(|e| panic!("{path:?}: {e}"));
        let sc = cfg.scenario.unwrap_or_else(|| panic!("{path:?}: no [scenario] table"));
        let preset = fedasync::scenario::presets::named(&sc.name)
            .unwrap_or_else(|| panic!("{path:?}: scenario name {:?} is not a preset", sc.name));
        assert_eq!(sc, preset, "{path:?} drifted from preset {:?}", preset.name);
        checked += 1;
    }
    assert!(checked >= 3, "expected >= 3 scenario configs, pinned {checked}");
}

#[test]
fn shipped_config_files_parse_and_validate() {
    // The TOML files under configs/ are part of the public interface.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut seen = 0;
    for entry in std::fs::read_dir(&dir).expect("configs/ exists") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        let cfg = fedasync::config::ExperimentConfig::from_toml_file(&path)
            .unwrap_or_else(|e| panic!("{path:?}: {e}"));
        cfg.validate().unwrap_or_else(|e| panic!("{path:?}: {e}"));
        seen += 1;
    }
    assert!(seen >= 2, "expected shipped configs, found {seen}");
}

// ---------------------------------------------------------------------
// Compute-plane properties (analysis::quadratic).
// ---------------------------------------------------------------------

#[test]
fn prop_quadratic_fast_evaluator_matches_exact_loop() {
    // The O(dim) moment evaluator must stay within 1e-6 relative of the
    // exact O(n·dim) loop for random x (the bitwise fused-kernel pin
    // lives next to the private state in analysis::quadratic's tests).
    use fedasync::analysis::quadratic::QuadraticProblem;
    check("quadratic-fast-evaluator", 100, |g| {
        let n = g.size(1, 16);
        let dim = g.size(1, 48);
        let spread = g.f64_in(0.5, 4.0);
        let seed = g.rng.next_u64();
        let p = QuadraticProblem::new(n, dim, 0.5, 2.0, spread, 0.0, 1, seed);
        for _ in 0..4 {
            let x = g.vec_f32(dim, 4.0);
            let exact = p.global_f(&x);
            let fast = p.global_f_fast(&x);
            prop_ensure!(
                (fast - exact).abs() <= 1e-6 * exact.abs().max(1e-12),
                "n={n} dim={dim}: exact {exact} vs fast {fast}"
            );
        }
        // The gap is defined through the fast evaluator on both sides,
        // so it is exactly zero at the closed-form minimizer.
        prop_ensure!(p.gap(&p.x_star()) == 0.0, "gap(x*) != 0");
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Aggregation-strategy properties (coordinator::aggregator).
// ---------------------------------------------------------------------

/// Minimal Trainer for driving `Updater` on the native mix path (the
/// aggregator tests never touch training or evaluation).
struct NullTrainer;

impl fedasync::coordinator::Trainer for NullTrainer {
    fn param_count(&self) -> usize {
        0
    }
    fn init_params(&self, _: usize) -> Result<Vec<f32>, fedasync::runtime::RuntimeError> {
        unreachable!("aggregator properties feed updates directly")
    }
    fn local_train(
        &self,
        _: &[f32],
        _: Option<&[f32]>,
        _: &mut fedasync::federated::device::SimDevice,
        _: &fedasync::federated::data::Dataset,
        _: f32,
        _: f32,
        _: &mut fedasync::coordinator::TaskScratch,
    ) -> Result<(Vec<f32>, f32), fedasync::runtime::RuntimeError> {
        unreachable!()
    }
    fn evaluate(
        &self,
        _: &[f32],
        _: &fedasync::federated::data::Dataset,
    ) -> Result<fedasync::runtime::EvalMetrics, fedasync::runtime::RuntimeError> {
        unreachable!()
    }
    fn local_iters(&self) -> usize {
        1
    }
}

#[test]
fn prop_buffered_blend_normalizes() {
    // The staged blend must equal the explicitly normalized
    // staleness-weighted mean Σ (wᵢ/W)·xᵢ with Σ wᵢ/W = 1 — in
    // particular, identical inputs blend to themselves regardless of the
    // staleness mix.
    use fedasync::coordinator::aggregator::{AggregateDecision, Aggregator, Buffered};
    use fedasync::coordinator::staleness::AlphaController;
    check("buffered-blend-normalizes", 100, |g| {
        let k = g.size(1, 12);
        let dim = g.size(1, 40);
        let func = random_staleness_fn(g);
        let ctl = AlphaController::new(
            g.f64_in(0.01, 1.0),
            1.0,
            usize::MAX,
            &StalenessConfig { max: 64, func, drop_above: None },
        );
        let mut agg = Buffered::new(ctl, k, None);
        let current = vec![0.0f32; dim];
        let mut updates: Vec<(Vec<f32>, u64)> = Vec::new();
        for i in 0..k {
            let x = g.vec_f32(dim, 2.0);
            let s = 1 + g.index(16) as u64;
            let d = agg.offer(&x, &current, s, i as u64 + 1);
            updates.push((x, s));
            if i + 1 < k {
                prop_ensure!(d == AggregateDecision::Buffer, "early commit at {i}");
            } else {
                prop_ensure!(
                    matches!(d, AggregateDecision::ApplyStaged { alpha } if alpha > 0.0 && alpha <= 1.0),
                    "k-th offer must commit with α in (0,1], got {d:?}"
                );
            }
        }
        let blend = agg.take_staged().expect("staged blend");
        // Reference: direct normalized weighted mean in f64.
        let weights: Vec<f64> = updates
            .iter()
            .map(|(_, s)| func.eval(*s).max(f64::MIN_POSITIVE))
            .collect();
        let w_total: f64 = weights.iter().sum();
        prop_ensure!(
            (weights.iter().map(|w| w / w_total).sum::<f64>() - 1.0).abs() < 1e-12,
            "normalized weights must sum to 1"
        );
        for j in 0..dim {
            let want: f64 = updates
                .iter()
                .zip(&weights)
                .map(|((x, _), w)| (w / w_total) * x[j] as f64)
                .sum();
            let got = blend[j] as f64;
            prop_ensure!(
                (got - want).abs() < 1e-3,
                "blend[{j}] = {got} vs normalized mean {want} (k={k})"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_buffered_flush_applies_every_update_exactly_once() {
    // Over a random offer stream, every accepted update is absorbed into
    // exactly one commit: floor(n/k) commits happen in-stream and the
    // drain commits the tail exactly once, leaving the buffer empty.
    use fedasync::coordinator::aggregator::Buffered;
    use fedasync::coordinator::staleness::AlphaController;
    use fedasync::coordinator::updater::{MixEngine, Updater};
    check("buffered-flush-exactly-once", 100, |g| {
        let k = g.size(1, 8);
        let n = g.size(0, 40);
        let dim = g.size(1, 8);
        let ctl = AlphaController::new(
            g.f64_in(0.01, 1.0),
            1.0,
            usize::MAX,
            &StalenessConfig { max: 64, func: random_staleness_fn(g), drop_above: None },
        );
        let mut u = Updater::new(Box::new(Buffered::new(ctl, k, None)), MixEngine::Native);
        let mut store = ModelStore::new(vec![0.0f32; dim], 4);
        let (mut absorbed, mut commits) = (0usize, 0usize);
        for _ in 0..n {
            let x = g.vec_f32(dim, 1.0);
            let tau = store.current_version();
            let out = u.apply(&NullTrainer, &mut store, &x, tau).map_err(|e| e.to_string())?;
            absorbed += out.buffered as usize;
            commits += out.applied as usize;
        }
        prop_ensure!(absorbed == n, "absorbed {absorbed} of {n} accepted updates");
        prop_ensure!(commits == n / k, "in-stream commits {commits} != {n}/{k}");
        prop_ensure!(
            store.current_version() == (n / k) as u64,
            "version {} != commit count",
            store.current_version()
        );
        let tail = u.drain(&NullTrainer, &mut store).map_err(|e| e.to_string())?;
        prop_ensure!(
            tail.is_some() == (n % k != 0),
            "drain committed {:?} with tail of {}",
            tail.is_some(),
            n % k
        );
        prop_ensure!(
            store.current_version() == (n / k + (n % k != 0) as usize) as u64,
            "post-drain version {}",
            store.current_version()
        );
        // Exactly once: a second drain finds nothing.
        prop_ensure!(
            u.drain(&NullTrainer, &mut store).map_err(|e| e.to_string())?.is_none(),
            "drain must be idempotent"
        );
        Ok(())
    });
}

#[test]
fn prop_distance_adaptive_alpha_in_unit_interval() {
    // Whatever the geometry — zero models, huge updates, degenerate
    // clamps — a non-dropped decision's α stays in (0, 1].
    use fedasync::coordinator::aggregator::{AggregateDecision, Aggregator, DistanceAdaptive};
    use fedasync::coordinator::staleness::AlphaController;
    check("distance-alpha-unit-interval", 200, |g| {
        let dim = g.size(1, 32);
        let drop_above = g.bool().then(|| g.index(16) as u64);
        let ctl = AlphaController::new(
            g.f64_in(0.01, 1.0),
            g.f64_in(0.1, 1.0),
            g.index(100),
            &StalenessConfig { max: 64, func: random_staleness_fn(g), drop_above },
        );
        let lo = g.f64_in(1e-6, 10.0);
        let hi = lo + g.f64_in(0.0, 1e3);
        let mut agg = DistanceAdaptive::new(ctl, lo, hi);
        for _ in 0..20 {
            let scale = [0.0f32, 1e-20, 1.0, 1e18][g.index(4)];
            let current: Vec<f32> = g.vec_f32(dim, 1.0).iter().map(|v| v * scale).collect();
            let x_new = g.vec_f32(dim, [0.0f32, 1.0, 1e15][g.index(3)].max(1e-3));
            let s = 1 + g.index(32) as u64;
            let t = 1 + g.index(200) as u64;
            match agg.offer(&x_new, &current, s, t) {
                AggregateDecision::Apply { alpha } => {
                    prop_ensure!(
                        alpha > 0.0 && alpha <= 1.0 && alpha.is_finite(),
                        "α = {alpha} escaped (0, 1] (lo={lo} hi={hi} s={s})"
                    );
                    if let Some(cut) = drop_above {
                        prop_ensure!(s <= cut, "applied above the cutoff s={s} cut={cut}");
                    }
                }
                AggregateDecision::Drop => {
                    let cut = drop_above.ok_or("drop without a drop policy")?;
                    prop_ensure!(s > cut, "dropped below the cutoff (s={s}, cut={cut})");
                }
                other => return Err(format!("distance never buffers, got {other:?}")),
            }
        }
        Ok(())
    });
}

#[test]
fn prop_event_queue_matches_reference_model() {
    // Model-based differential: the binary-heap queue vs a brute-force
    // Vec reference that re-derives the pop order from first principles
    // (min by time, ties by insertion seq; `schedule_at` clamps into the
    // present; `now` is the last popped timestamp).  The fuzz target
    // `event_queue` runs the same model over raw byte streams; this is
    // the seeded tier-1 twin with a 1k-case budget.
    check("event-queue-model", 1000, |g| {
        let mut q: EventQueue<u32> = EventQueue::new();
        let mut model: Vec<(f64, u64, u32)> = Vec::new();
        let mut next_seq = 0u64;
        let mut now = 0.0f64;
        let ops = g.size(1, 60);
        for i in 0..ops {
            match g.index(3) {
                0 => {
                    let at = g.f64_in(-5.0, 50.0);
                    q.schedule_at(at, i as u32);
                    model.push((at.max(now), next_seq, i as u32));
                    next_seq += 1;
                }
                1 => {
                    let delay = g.f64_in(0.0, 10.0);
                    q.schedule_in(delay, i as u32);
                    model.push((now + delay, next_seq, i as u32));
                    next_seq += 1;
                }
                _ => {
                    let expect = model
                        .iter()
                        .enumerate()
                        .min_by(|(_, a), (_, b)| {
                            a.0.total_cmp(&b.0).then(a.1.cmp(&b.1))
                        })
                        .map(|(idx, _)| idx);
                    match (q.pop(), expect) {
                        (None, None) => {}
                        (Some(ev), Some(idx)) => {
                            let (at, seq, payload) = model.remove(idx);
                            prop_ensure!(
                                ev.at == at && ev.seq == seq && ev.payload == payload,
                                "pop mismatch: got ({}, {}, {}), model ({at}, {seq}, {payload})",
                                ev.at,
                                ev.seq,
                                ev.payload
                            );
                            now = at;
                        }
                        (got, want) => {
                            return Err(format!(
                                "emptiness disagreement: queue {:?}, model {:?}",
                                got.map(|e| e.payload),
                                want
                            ))
                        }
                    }
                }
            }
            prop_ensure!(q.len() == model.len(), "length drift after op {i}");
            prop_ensure!(q.now() == now, "clock drift: {} vs {now}", q.now());
        }
        Ok(())
    });
}

#[test]
fn prop_wheel_matches_heap_pop_order() {
    // Differential: the timer-wheel `EventQueue` vs the retained
    // binary-heap reference (`HeapEventQueue`) must agree on every pop —
    // (time, seq, payload) bitwise — across workloads engineered to
    // stress exactly where a calendar queue could diverge from a heap:
    // exact timestamp ties (seq tie-break), coarse-bucket collisions
    // (times quantized onto bucket boundaries), and horizon rollover
    // through the L1 wheel and the overflow heap, at several
    // granularities.  The `event_queue` fuzz target runs the same
    // three-way differential over raw byte streams.
    check("wheel-vs-heap", 300, |g| {
        let granularity = [1e-3, 0.01, 0.5, 10.0][g.index(4)];
        let horizon = [5.0, 100.0, 50_000.0][g.index(3)];
        let mut wheel: EventQueue<usize> = EventQueue::with_granularity(granularity);
        let mut heap: HeapEventQueue<usize> = HeapEventQueue::new();
        let ops = g.size(1, 400);
        let mut last_at = 0.0f64;
        for i in 0..ops {
            match g.index(6) {
                0 | 1 => {
                    let at = g.f64_in(0.0, horizon);
                    last_at = at;
                    wheel.schedule_at(at, i);
                    heap.schedule_at(at, i);
                }
                2 => {
                    // Exact tie with an earlier schedule: pops must stay
                    // FIFO by seq.
                    wheel.schedule_at(last_at, i);
                    heap.schedule_at(last_at, i);
                }
                3 => {
                    // Bucket-boundary collision: a time landing exactly on
                    // a multiple of the wheel granularity.
                    let at = (g.f64_in(0.0, horizon) / granularity).floor() * granularity;
                    last_at = at;
                    wheel.schedule_at(at, i);
                    heap.schedule_at(at, i);
                }
                4 => {
                    let delay = g.f64_in(0.0, horizon / 10.0);
                    wheel.schedule_in(delay, i);
                    heap.schedule_in(delay, i);
                }
                _ => match (wheel.pop(), heap.pop()) {
                    (None, None) => {}
                    (Some(w), Some(h)) => {
                        prop_ensure!(
                            w.at.to_bits() == h.at.to_bits()
                                && w.seq == h.seq
                                && w.payload == h.payload,
                            "pop diverged: wheel ({}, {}, {}) vs heap ({}, {}, {})",
                            w.at,
                            w.seq,
                            w.payload,
                            h.at,
                            h.seq,
                            h.payload
                        );
                    }
                    (w, h) => {
                        return Err(format!(
                            "emptiness diverged: wheel {:?} vs heap {:?}",
                            w.map(|e| e.payload),
                            h.map(|e| e.payload)
                        ))
                    }
                },
            }
            prop_ensure!(wheel.len() == heap.len(), "length drift after op {i}");
            prop_ensure!(
                wheel.now().to_bits() == heap.now().to_bits(),
                "clock drift: {} vs {}",
                wheel.now(),
                heap.now()
            );
        }
        // Full drain: the tail (which exercises L1 scans and overflow
        // re-homing) must match event for event.
        loop {
            match (wheel.pop(), heap.pop()) {
                (None, None) => break,
                (Some(w), Some(h)) => {
                    prop_ensure!(
                        w.at.to_bits() == h.at.to_bits() && w.seq == h.seq && w.payload == h.payload,
                        "drain diverged at seq {} vs {}",
                        w.seq,
                        h.seq
                    );
                }
                (w, h) => {
                    return Err(format!(
                        "drain emptiness diverged: wheel {:?} vs heap {:?}",
                        w.map(|e| e.payload),
                        h.map(|e| e.payload)
                    ))
                }
            }
        }
        prop_ensure!(wheel.is_empty() && heap.is_empty(), "drain left events behind");
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Serving-plane properties (serving::wire, aggregator admission gate).
// ---------------------------------------------------------------------

fn gen_wire_params(g: &mut Gen) -> Vec<f32> {
    // Dims include 0: an empty parameter vector is a legal frame.
    let dim = g.size(0, 64);
    g.vec_f32(dim, 1e6)
}

fn gen_wire_frame(g: &mut Gen) -> fedasync::serving::Frame {
    use fedasync::serving::Frame;
    match g.index(8) {
        0 => Frame::PullModel,
        1 => Frame::ModelSnapshot { version: g.rng.next_u64() >> 20, params: gen_wire_params(g) },
        2 => {
            // Untracked update: client == device, seq == 0 keeps the
            // legacy kind-2 short encoding.
            let device = g.index(1 << 20) as u32;
            Frame::ClientUpdate {
                device,
                tau: g.rng.next_u64() >> 20,
                loss: g.f64_in(0.0, 1e6) as f32,
                client: u64::from(device),
                seq: 0,
                params: gen_wire_params(g),
            }
        }
        3 => Frame::Ack {
            version: g.rng.next_u64() >> 20,
            applied: g.bool(),
            staleness: g.index(1 << 16) as u64,
        },
        4 => Frame::Shed { retry_after_ms: g.index(1 << 16) as u32 },
        5 => Frame::Control {
            body: (0..g.size(0, 40)).map(|_| char::from(32 + g.index(90) as u8)).collect(),
        },
        6 => Frame::ControlReply {
            body: (0..g.size(0, 40)).map(|_| char::from(32 + g.index(90) as u8)).collect(),
        },
        // Tracked update: nonzero seq forces the extended kind-7 frame.
        _ => Frame::ClientUpdate {
            device: g.index(1 << 20) as u32,
            tau: g.rng.next_u64() >> 20,
            loss: g.f64_in(0.0, 1e6) as f32,
            client: 1 + (g.rng.next_u64() >> 32),
            seq: 1 + g.index(1 << 20) as u64,
            params: gen_wire_params(g),
        },
    }
}

#[test]
fn prop_wire_frames_roundtrip_and_truncate_safely() {
    use fedasync::serving::wire::{decode, encode};
    check("wire-roundtrip", 300, |g| {
        let frame = gen_wire_frame(g);
        let bytes = encode(&frame);
        let (back, consumed) = decode(&bytes)
            .map_err(|e| format!("{frame:?}: decode failed: {e}"))?
            .ok_or_else(|| format!("{frame:?}: complete frame decoded as incomplete"))?;
        prop_ensure!(back == frame, "round trip changed the frame: {frame:?} -> {back:?}");
        prop_ensure!(
            consumed == bytes.len(),
            "consumed {consumed} of {} encoded bytes",
            bytes.len()
        );
        // Any strict prefix is "wait for more bytes" — never an error,
        // never a phantom frame.  A random cut plus the two canonical
        // boundaries (empty, one-before-complete).
        for cut in [0, g.index(bytes.len()), bytes.len() - 1] {
            let got = decode(&bytes[..cut])
                .map_err(|e| format!("{frame:?}: prefix [..{cut}] errored: {e}"))?;
            prop_ensure!(got.is_none(), "{frame:?}: prefix [..{cut}] decoded as complete");
        }
        Ok(())
    });
}

#[test]
fn prop_wire_rejects_non_finite_floats() {
    use fedasync::serving::wire::{decode, encode, WireError};
    use fedasync::serving::Frame;
    check("wire-non-finite", 200, |g| {
        let dim = g.size(1, 32);
        let mut params = g.vec_f32(dim, 10.0);
        let bad = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY][g.index(3)];
        let poison_loss = g.bool();
        let mut loss = g.f64_in(0.0, 10.0) as f32;
        if poison_loss {
            loss = bad;
        } else {
            params[g.index(dim)] = bad;
        }
        let frame = if poison_loss || g.bool() {
            Frame::ClientUpdate { device: 0, tau: 1, loss, client: 0, seq: 0, params }
        } else {
            Frame::ModelSnapshot { version: 1, params }
        };
        match decode(&encode(&frame)) {
            Err(WireError::NonFinite) => Ok(()),
            other => Err(format!("{bad} slipped through the codec: {other:?}")),
        }
    });
}

#[test]
fn prop_admission_gate_sheds_exactly_the_overflow() {
    use fedasync::config::StalenessConfig;
    use fedasync::coordinator::aggregator::{AdmissionGate, FedAsync, ShedGate};
    use fedasync::coordinator::staleness::AlphaController;
    use fedasync::coordinator::updater::{MixEngine, Updater};
    use std::sync::{Arc, Barrier};

    // Capacity Q, N > Q racing admissions: exactly Q enter and N − Q are
    // refused — then, with the gate held saturated, every offer through a
    // ShedGate-wrapped updater sheds (version frozen), and once the slots
    // release every offer applies.  Totals reconcile exactly:
    // offers == applied + shed, version == applied.
    check("admission-backpressure", 60, |g| {
        let q = g.size(1, 8);
        let n = q + g.size(1, 8);
        let gate = Arc::new(AdmissionGate::new(q));
        let barrier = Arc::new(Barrier::new(n));
        let admitted: Vec<bool> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|_| {
                    let gate = Arc::clone(&gate);
                    let barrier = Arc::clone(&barrier);
                    s.spawn(move || {
                        barrier.wait();
                        gate.try_enter()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("gate thread")).collect()
        });
        let entered = admitted.iter().filter(|&&a| a).count();
        prop_ensure!(entered == q, "{entered} of {n} admitted, want exactly {q}");
        prop_ensure!(gate.inflight() == q && gate.is_saturated(), "gate not saturated");

        let dim = g.size(1, 16);
        let ctl = AlphaController::new(
            g.f64_in(0.01, 1.0),
            1.0,
            usize::MAX,
            &StalenessConfig { max: 64, func: random_staleness_fn(g), drop_above: None },
        );
        let shed_gate = ShedGate::new(Box::new(FedAsync::new(ctl)), Arc::clone(&gate));
        let mut u = Updater::new(Box::new(shed_gate), MixEngine::Native);
        let mut store = ModelStore::new(vec![0.0f32; dim], 4);
        let (mut applied, mut shed) = (0usize, 0usize);
        let while_full = g.size(1, 10);
        for _ in 0..while_full {
            let x = g.vec_f32(dim, 1.0);
            let tau = store.current_version();
            let out = u.apply(&NullTrainer, &mut store, &x, tau).map_err(|e| e.to_string())?;
            prop_ensure!(out.shed && !out.applied && !out.buffered, "saturated offer not shed");
            prop_ensure!(out.alpha_eff == 0.0, "shed leaked α = {}", out.alpha_eff);
            shed += out.shed as usize;
        }
        prop_ensure!(store.current_version() == 0, "shed offers advanced the model");
        for _ in 0..q {
            gate.leave();
        }
        let after_release = g.size(1, 10);
        for _ in 0..after_release {
            let x = g.vec_f32(dim, 1.0);
            let tau = store.current_version();
            let out = u.apply(&NullTrainer, &mut store, &x, tau).map_err(|e| e.to_string())?;
            prop_ensure!(out.applied && !out.shed, "free-gate offer refused");
            applied += out.applied as usize;
        }
        prop_ensure!(
            applied + shed == while_full + after_release,
            "offers leaked: {applied} applied + {shed} shed != {}",
            while_full + after_release
        );
        prop_ensure!(
            store.current_version() == applied as u64,
            "version {} != applied count {applied}",
            store.current_version()
        );
        Ok(())
    });
}

#[test]
fn prop_soa_behavior_matches_reference() {
    // The SoA-compiled ScenarioBehavior vs the retained per-client
    // reference implementation: same seed, same scenario, same fleet ⇒
    // draw-for-draw, bit-for-bit identical decisions on every query.
    // Each behavior consumes its own RNG clone of one shared seed; after
    // an identical op sequence both cursors must sit at the same stream
    // position (the final draw comparison), which catches any draw-count
    // drift — e.g. the zero-fault `delivery` early-return consuming a
    // draw on one side only.  Half the cases run the shipped presets
    // (including `million_fleet`), half run randomized scenarios.
    use fedasync::scenario::reference::ReferenceScenarioBehavior;
    use fedasync::scenario::{
        presets, ChurnPhase, ClientBehavior, FaultModel, ScenarioBehavior, ScenarioConfig,
        SpeedTier, StragglerBurst,
    };
    use fedasync::util::rng::Rng;

    check("soa-behavior-vs-reference", 80, |g| {
        let sc = if g.bool() {
            let names = presets::preset_names();
            let name = names[g.index(names.len())];
            presets::named(name).ok_or_else(|| format!("missing preset {name}"))?
        } else {
            let mut sc = ScenarioConfig { name: "soa-prop".into(), ..ScenarioConfig::default() };
            if g.bool() {
                sc.tiers = (0..g.size(1, 4))
                    .map(|_| SpeedTier {
                        fraction: g.f64_in(0.05, 1.0),
                        speed: g.f64_in(0.05, 4.0),
                        latency_mu: g.f64_in(-4.0, 0.0),
                        latency_sigma: g.f64_in(0.0, 1.5),
                    })
                    .collect();
            }
            if g.bool() {
                let mut at = 0.0;
                sc.churn = (0..g.size(1, 3))
                    .map(|_| {
                        at = g.f64_in(at, 1.0);
                        ChurnPhase { at, present: g.f64_in(0.05, 1.0) }
                    })
                    .collect();
            }
            if g.bool() {
                sc.bursts = (0..g.size(1, 3))
                    .map(|_| {
                        let from = g.f64_in(0.0, 0.9);
                        StragglerBurst {
                            from,
                            until: g.f64_in(from, 1.0),
                            fraction: g.f64_in(0.01, 1.0),
                            slowdown: g.f64_in(1.0, 16.0),
                        }
                    })
                    .collect();
            }
            if g.bool() {
                // Faulty transport half the time; the other half keeps the
                // zero-fault delivery fast path (which must consume no
                // draws on either side).
                sc.faults =
                    FaultModel { drop_prob: g.f64_in(0.0, 0.4), duplicate_prob: g.f64_in(0.0, 0.4) };
            }
            sc
        };
        let n = g.size(1, 300);
        let seed = g.index(1_000_000) as u64;
        let soa = ScenarioBehavior::new(&sc, n, seed);
        let rf = ReferenceScenarioBehavior::new(&sc, n, seed);
        prop_ensure!(soa.label() == rf.label(), "labels diverged");

        let mut rng_soa = Rng::seed_from(seed ^ 0xD1FF);
        let mut rng_ref = Rng::seed_from(seed ^ 0xD1FF);
        for op in 0..64 {
            let d = g.index(n + 2); // past-the-fleet indices exercise the clamp
            let p = g.f64_in(-0.1, 1.1);
            match g.index(6) {
                0 => prop_ensure!(
                    soa.is_present(d, p) == rf.is_present(d, p),
                    "is_present({d}, {p}) diverged at op {op}"
                ),
                1 => prop_ensure!(
                    soa.present_count(p) == rf.present_count(p),
                    "present_count({p}) diverged at op {op}"
                ),
                2 => {
                    let (a, b) = (soa.slowdown(d, p), rf.slowdown(d, p));
                    prop_ensure!(
                        a.to_bits() == b.to_bits(),
                        "slowdown({d}, {p}) diverged at op {op}: {a} vs {b}"
                    );
                }
                3 => {
                    let (a, b) =
                        (soa.link_latency(d, &mut rng_soa), rf.link_latency(d, &mut rng_ref));
                    prop_ensure!(
                        a.to_bits() == b.to_bits(),
                        "link_latency({d}) diverged at op {op}: {a} vs {b}"
                    );
                }
                4 => {
                    let max = 1 + g.index(64) as u64;
                    let (a, b) = (
                        soa.sample_staleness(d, p, max, &mut rng_soa),
                        rf.sample_staleness(d, p, max, &mut rng_ref),
                    );
                    prop_ensure!(
                        a == b,
                        "sample_staleness({d}, {p}, {max}) diverged at op {op}: {a} vs {b}"
                    );
                }
                _ => {
                    let (a, b) =
                        (soa.delivery(d, p, &mut rng_soa), rf.delivery(d, p, &mut rng_ref));
                    prop_ensure!(a == b, "delivery({d}, {p}) diverged at op {op}: {a:?} vs {b:?}");
                }
            }
        }
        // Draw-count pin: identical op sequences must leave both RNG
        // cursors at the same stream position.
        prop_ensure!(
            rng_soa.f64().to_bits() == rng_ref.f64().to_bits(),
            "RNG streams desynchronized: one side consumed a different number of draws"
        );
        Ok(())
    });
}
