//! Loopback conformance for the serving plane.
//!
//! A real `TcpListener` on 127.0.0.1, real swarm-client processes' worth
//! of threads speaking the wire protocol, and the same closed-form
//! quadratic compute plane the cross-mode conformance suite uses — so a
//! *served* run can be banded directly against the in-process threaded
//! driver under the stress presets (`scenario_straggler`,
//! `scenario_churn`): every mode learns, final losses share a band, and
//! the staleness histograms' supports overlap.  The accounting path is
//! shared (`UpdaterCore::offer`), so any divergence here is a serving-
//! plane bug, not a tolerance problem.
//!
//! Also pinned: the shutdown drain contract (every version increment was
//! acked to exactly one client; nothing acked is ever lost), and that
//! misbehaving peers — half-written headers, garbage bytes, mid-run
//! disconnects — cannot wedge the drain or the epoch target.

use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use fedasync::analysis::quadratic::{dummy_dataset, dummy_fleet, QuadraticProblem};
use fedasync::config::{ExecMode, ExperimentConfig, LocalUpdate, ServingConfig, StalenessFn};
use fedasync::coordinator::server::{run_server_core, serve_native, ComputeJob};
use fedasync::coordinator::Trainer;
use fedasync::federated::metrics::MetricsLog;
use fedasync::scenario;
use fedasync::serving::wire::encode;
use fedasync::serving::{
    run_quad_client, run_served_core, ClientLoop, ClientReport, Frame, ServingStats, SwarmClient,
};

const CONF_DEVICES: usize = 16;
const CONF_EPOCHS: usize = 120;
const CONF_SEED: u64 = 1;
const CLIENTS: usize = 3;

fn conformance_quad() -> QuadraticProblem {
    // Same problem as the cross-mode conformance suite in
    // integration_training.rs: mild gradient noise gives every execution
    // the same variance floor, keeping the shared loss band meaningful.
    QuadraticProblem::new(CONF_DEVICES, 6, 0.5, 2.0, 2.0, 0.05, 5, 3)
}

/// Same shrink the in-process conformance suite applies, plus the
/// serving block (threads mode is a validation requirement to serve).
fn conformance_shrink(cfg: &mut ExperimentConfig) {
    cfg.mode = ExecMode::Threads;
    cfg.epochs = CONF_EPOCHS;
    cfg.eval_every = CONF_EPOCHS / 4;
    cfg.repeats = 1;
    cfg.seed = CONF_SEED;
    cfg.gamma = 0.05;
    cfg.alpha = 0.6;
    cfg.alpha_decay = 1.0;
    cfg.alpha_decay_at = usize::MAX;
    cfg.local_update = LocalUpdate::Sgd;
    cfg.staleness.func = StalenessFn::Poly { a: 0.5 };
    cfg.federation.devices = CONF_DEVICES;
    cfg.worker_threads = CLIENTS;
    cfg.max_inflight = 4;
    cfg.serving = Some(ServingConfig::default());
    cfg.validate().expect("conformance serving config");
}

fn preset_cfg(name: &str) -> ExperimentConfig {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs").join(name);
    let mut cfg =
        ExperimentConfig::from_toml_file(&path).unwrap_or_else(|e| panic!("{path:?}: {e}"));
    assert!(cfg.scenario.is_some(), "{path:?} must carry a [scenario] table");
    conformance_shrink(&mut cfg);
    cfg
}

/// Plain config (no scenario): uniform population, every delivery lands.
fn plain_cfg(epochs: usize, eval_every: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    conformance_shrink(&mut cfg);
    cfg.epochs = epochs;
    cfg.eval_every = eval_every;
    cfg.validate().expect("plain serving config");
    cfg
}

/// The in-process threaded baseline over the native quadratic service.
fn run_threaded_baseline(cfg: &ExperimentConfig) -> MetricsLog {
    let p = conformance_quad();
    let init = p.init_params(CONF_SEED as usize).expect("init");
    let h = p.local_iters();
    let (job_tx, job_rx) = mpsc::channel::<ComputeJob>();
    let svc = std::thread::spawn(move || serve_native(conformance_quad(), CONF_DEVICES, job_rx));
    let behavior = scenario::behavior_for(cfg, CONF_DEVICES, CONF_SEED);
    let test = dummy_dataset();
    let log = run_server_core(cfg, CONF_SEED, &test, init, h, job_tx, behavior)
        .unwrap_or_else(|e| panic!("threaded baseline: {e}"));
    svc.join().expect("native service join");
    log
}

/// A full served run over 127.0.0.1: the engine behind `run_served_core`,
/// `clients` swarm-client threads doing pull → local-train → push with
/// backoff, and an optional hook fed the live address (rogue peers,
/// status probes).  Returns the server log, every client's report, and
/// the serving counters.
fn run_loopback(
    cfg: &ExperimentConfig,
    clients: usize,
    rogue: impl FnOnce(std::net::SocketAddr) + Send + 'static,
) -> (MetricsLog, Vec<ClientReport>, Arc<ServingStats>) {
    let p = conformance_quad();
    let init = p.init_params(CONF_SEED as usize).expect("init");
    let h = p.local_iters();
    let (job_tx, job_rx) = mpsc::channel::<ComputeJob>();
    let svc = std::thread::spawn(move || serve_native(conformance_quad(), CONF_DEVICES, job_rx));
    let behavior = scenario::behavior_for(cfg, CONF_DEVICES, CONF_SEED);
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr");
    let stats = Arc::new(ServingStats::default());

    let (done_tx, done_rx) = mpsc::channel();
    {
        let cfg = cfg.clone();
        let behavior = Arc::clone(&behavior);
        let stats = Arc::clone(&stats);
        std::thread::spawn(move || {
            let test = dummy_dataset();
            let result =
                run_served_core(&cfg, CONF_SEED, &test, init, h, job_tx, behavior, listener, stats);
            let _ = done_tx.send(result);
        });
    }

    let rogue_handle = std::thread::spawn(move || rogue(addr));

    let epochs = cfg.epochs as u64;
    let (gamma, rho) = (cfg.gamma, cfg.rho);
    let client_handles: Vec<_> = (0..clients)
        .map(|c| {
            let behavior = Arc::clone(&behavior);
            std::thread::spawn(move || {
                let trainer = conformance_quad();
                let mut fleet = dummy_fleet(CONF_DEVICES, 7);
                let data = dummy_dataset();
                let loop_cfg = ClientLoop {
                    behavior: behavior.as_ref(),
                    devices: CONF_DEVICES,
                    epochs,
                    gamma,
                    rho,
                    seed: CONF_SEED + 100 * (c as u64 + 1),
                    deadline: Duration::from_secs(120),
                    client_id: 0,
                    max_push_attempts: 0,
                    chaos: None,
                };
                run_quad_client(addr, &trainer, &mut fleet, &data, &loop_cfg)
                    .unwrap_or_else(|e| panic!("client {c}: {e}"))
            })
        })
        .collect();

    // Watchdog: a wedged drain fails the test instead of hanging the
    // suite (same idiom as server_core.rs).
    let result = done_rx
        .recv_timeout(Duration::from_secs(180))
        .expect("served engine deadlocked during run/teardown");
    let log = result.expect("served run failed");
    let reports: Vec<ClientReport> =
        client_handles.into_iter().map(|handle| handle.join().expect("client join")).collect();
    rogue_handle.join().expect("rogue peer join");
    svc.join().expect("native service join");
    (log, reports, stats)
}

/// Conformance bands shared with `scenario_presets_conform_across_modes`:
/// both runs learn, finals share a 100× band, staleness supports overlap.
fn assert_conformant(preset: &str, served: &MetricsLog, threaded: &MetricsLog) {
    let mut finals = Vec::new();
    for (mode, log) in [("served", served), ("threaded", threaded)] {
        let first = log.rows.first().expect("rows").test_loss;
        let last = log.rows.last().expect("rows").test_loss;
        assert!(
            last.is_finite() && last < first * 0.5,
            "{preset} {mode}: no learning ({first} -> {last})"
        );
        assert!(log.staleness_hist.total() > 0, "{preset} {mode}: empty staleness histogram");
        assert!(log.rows.iter().all(|r| r.clients >= 1 && r.clients <= CONF_DEVICES));
        finals.push(last);
    }
    let lo = finals.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = finals.iter().cloned().fold(0.0f64, f64::max);
    assert!(
        hi <= lo.max(1e-3) * 100.0,
        "{preset}: served vs threaded final losses diverged: {finals:?}"
    );
    let a: std::collections::BTreeSet<u64> = served.staleness_hist.support().into_iter().collect();
    let b: std::collections::BTreeSet<u64> =
        threaded.staleness_hist.support().into_iter().collect();
    assert!(
        a.intersection(&b).next().is_some(),
        "{preset}: staleness supports are disjoint: {a:?} vs {b:?}"
    );
}

fn conformance_case(preset_file: &str) {
    let cfg = preset_cfg(preset_file);
    let (served, reports, stats) = run_loopback(&cfg, CLIENTS, |_| {});
    let threaded = run_threaded_baseline(&cfg);
    assert_conformant(preset_file, &served, &threaded);
    // The serving counters and the client reports describe the same run.
    let acked: u64 = reports.iter().map(|r| r.acked).sum();
    assert!(acked > 0, "{preset_file}: no client push was ever acked");
    assert!(
        stats.acked.load(std::sync::atomic::Ordering::Relaxed) >= acked,
        "{preset_file}: server acked fewer than clients observed"
    );
}

#[test]
fn loopback_conforms_on_straggler_preset() {
    conformance_case("scenario_straggler.toml");
}

#[test]
fn loopback_conforms_on_churn_preset() {
    conformance_case("scenario_churn.toml");
}

#[test]
fn drain_acks_every_version_increment_exactly_once() {
    // The drain-before-exit contract: acks are sent only after an offer
    // resolved, so summing the clients' `applied` acks re-derives the
    // final model version exactly — nothing acked was lost in teardown,
    // and nothing applied went unacked.  No scenario: every delivery is
    // one copy, so applied acks and version increments are 1:1.
    let cfg = plain_cfg(40, 10);
    let (log, reports, stats) = run_loopback(&cfg, 2, |addr| {
        // Live control probe while the run is in flight.
        let mut probe = SwarmClient::connect(addr).expect("probe connect");
        let status = probe.status().expect("status round trip");
        assert!(status.version <= 40, "status version {} beyond target", status.version);
    });
    let last = log.rows.last().expect("rows");
    assert!(last.epoch >= 40, "stopped early at {}", last.epoch);
    let applied: u64 = reports.iter().map(|r| r.applied).sum();
    assert_eq!(
        applied,
        last.epoch as u64,
        "applied acks must re-derive the final version (drain lost or double-acked an update)"
    );
    let acked: u64 = reports.iter().map(|r| r.acked).sum();
    assert!(acked >= applied, "acked {acked} < applied {applied}");
    // Counter cross-check: the server never acks more than it admitted,
    // and every admitted update was answered (acked or shed).
    let s_admitted = stats.admitted.load(std::sync::atomic::Ordering::Relaxed);
    let s_acked = stats.acked.load(std::sync::atomic::Ordering::Relaxed);
    let s_shed = stats.shed.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(s_acked, acked, "server-side ack count must match the clients' view");
    assert!(s_acked <= s_admitted, "acked {s_acked} > admitted {s_admitted}");
    assert!(
        s_acked + s_shed >= s_admitted,
        "admitted updates left unanswered: admitted {s_admitted}, acked {s_acked}, shed {s_shed}"
    );
}

#[test]
fn stalled_reader_cannot_pin_a_handler_past_its_write_timeout() {
    // A peer that pumps requests but never drains replies: the handler's
    // reply writes back up through both TCP windows and block.  Without a
    // write timeout that handler thread is pinned forever (and the
    // shutdown drain would wedge joining it); with one, the write fails,
    // the peer is dropped, and the run finishes on the healthy clients.
    let mut cfg = plain_cfg(40, 10);
    cfg.serving.as_mut().expect("serving block").write_timeout_ms = 150;
    let (log, reports, _stats) = run_loopback(&cfg, 2, |addr| {
        let mut stall = TcpStream::connect(addr).expect("staller connect");
        // Our own writes must also fail once the request direction backs
        // up, or this hook would block in write_all instead of stalling.
        stall
            .set_write_timeout(Some(Duration::from_millis(100)))
            .expect("staller write timeout");
        let frame = encode(&Frame::PullModel);
        while stall.write_all(&frame).is_ok() {}
        // Keep the wedged socket open while the server recovers: the
        // handler must escape via its write timeout, not via our EOF.
        std::thread::sleep(Duration::from_millis(500));
        drop(stall);
    });
    let last = log.rows.last().expect("rows");
    assert!(last.epoch >= 40, "a stalled reader pinned the run at {}", last.epoch);
    assert!(reports.iter().map(|r| r.acked).sum::<u64>() > 0, "healthy clients starved");
}

#[test]
fn hostile_peers_and_mid_run_disconnects_do_not_wedge_the_drain() {
    // Three flavors of misbehaving peer against a live run: a half-written
    // header (valid 3-byte prefix, then gone), pure garbage bytes, and a
    // protocol-clean client that pulls once and vanishes.  The healthy
    // clients must still carry the run to its epoch target and the
    // shutdown drain must complete (watchdog-enforced inside
    // run_loopback).
    let cfg = plain_cfg(40, 10);
    let (log, reports, stats) = run_loopback(&cfg, 2, |addr| {
        let mut half = TcpStream::connect(addr).expect("half-frame peer connect");
        half.write_all(&[0xA5, 0xFD, 0x01]).expect("half-frame write");
        drop(half); // handler sees EOF mid-frame and must just drop us

        let mut garbage = TcpStream::connect(addr).expect("garbage peer connect");
        let _ = garbage.write_all(&[0u8; 16]); // BadMagic: peer gets dropped
        drop(garbage);

        let mut quitter = SwarmClient::connect(addr).expect("quitter connect");
        let (version, params) = quitter.pull().expect("quitter pull");
        assert!(version <= 40, "snapshot version {version} beyond the target");
        assert!(!params.is_empty(), "snapshot carried no parameters");
        drop(quitter); // mid-run disconnect with no goodbye
    });
    let last = log.rows.last().expect("rows");
    assert!(last.epoch >= 40, "hostile peers stalled the run at {}", last.epoch);
    assert!(reports.iter().map(|r| r.acked).sum::<u64>() > 0, "healthy clients starved");
    // All five peers were accepted (2 healthy + 3 misbehaving), plus the
    // shutdown self-connect; none of them wedged accounting.
    assert!(
        stats.connections.load(std::sync::atomic::Ordering::Relaxed) >= 5,
        "expected every peer to reach the acceptor"
    );
}
